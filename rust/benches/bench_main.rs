//! Benchmark harness (criterion is unavailable offline — this is a
//! criterion-lite: warmup, timed iterations, mean ± σ, throughput rows).
//!
//! One bench per paper table/figure (the regeneration cost of each
//! experiment) plus microbenches of the framework's own hot paths: the
//! mapper parameter search, the tile-level matmul simulation, the systolic
//! LUT, the link model, and the JSON substrate.
//!
//! Run: `cargo bench`.

use llmcompass::arch::systolic::{Array, Dataflow, SystolicLut, Tile};
use llmcompass::experiments::{self, Ctx};
use llmcompass::graph::layer::Phase;
use llmcompass::graph::{inference::Simulator, ModelConfig};
use llmcompass::hardware::presets;
use llmcompass::hardware::DType;
use llmcompass::perf::mapper::{search, SearchBudget};
use llmcompass::perf::matmul::Shape;
use llmcompass::util::json::{num, obj, s, Json};
use llmcompass::util::stats::Welford;
use std::time::Instant;

struct Bench {
    rows: Vec<(String, f64, f64, u32, String)>,
}

impl Bench {
    fn new() -> Self {
        Bench { rows: Vec::new() }
    }

    /// Run `f` repeatedly: `warmup` throwaway iters, then time until
    /// either `max_iters` or ~1 s elapses. Records mean ± σ per iter.
    fn run<F: FnMut()>(&mut self, name: &str, note: &str, warmup: u32, max_iters: u32, mut f: F) {
        for _ in 0..warmup {
            f();
        }
        let mut w = Welford::default();
        let budget = Instant::now();
        for _ in 0..max_iters.max(1) {
            let t0 = Instant::now();
            f();
            w.push(t0.elapsed().as_secs_f64());
            if budget.elapsed().as_secs_f64() > 1.0 {
                break;
            }
        }
        self.rows
            .push((name.to_string(), w.mean(), w.stddev(), w.count() as u32, note.to_string()));
        eprintln!("  {name}: {} ± {} ({} iters)", fmt(w.mean()), fmt(w.stddev()), w.count());
    }

    fn report(&self) {
        println!("\n== benchmark results ==");
        println!("{:<28} {:>12} {:>12} {:>6}  note", "bench", "mean", "sigma", "iters");
        for (name, mean, sd, n, note) in &self.rows {
            println!("{name:<28} {:>12} {:>12} {n:>6}  {note}", fmt(*mean), fmt(*sd));
        }
    }
}

fn fmt(s: f64) -> String {
    llmcompass::util::fmt_seconds(s)
}

/// Record the mapper-engine rows in BENCH_mapper.json at the repo root —
/// rounds simulated + wall time per mode, the engine's perf baseline.
fn write_mapper_baseline(rows: Vec<Json>) {
    let doc = obj(vec![
        ("generated_by", s("cargo bench (benches/bench_main.rs)")),
        ("device", s("a100")),
        ("benches", Json::Arr(rows)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_mapper.json");
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => eprintln!("  wrote {}", path.display()),
        Err(e) => eprintln!("  warning: could not write {}: {e}", path.display()),
    }
}

/// Record the serving rows in BENCH_serve.json at the repo root — wall
/// time plus the shared latency oracle's deterministic simulator-call
/// count (the raw-speed pass's perf baseline: the counters move only if
/// the bucketing or sharing changes, so they regress loudly).
fn write_serve_baseline(rows: Vec<Json>) {
    let doc = obj(vec![
        ("generated_by", s("cargo bench (benches/bench_main.rs)")),
        ("device", s("a100")),
        ("benches", Json::Arr(rows)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serve.json");
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => eprintln!("  wrote {}", path.display()),
        Err(e) => eprintln!("  warning: could not write {}: {e}", path.display()),
    }
}

fn main() {
    let mut b = Bench::new();
    eprintln!("llmcompass benchmarks (criterion-lite)");

    // --- framework hot paths -----------------------------------------------
    let lut = SystolicLut::new();
    let arr = Array { rows: 16, cols: 16, dataflow: Dataflow::WeightStationary };
    b.run("systolic_analytical", "one tile timing", 100, 100_000, || {
        std::hint::black_box(llmcompass::arch::systolic::cycles_analytical(
            Tile { m: 128, k: 64, n: 64 },
            arr,
        ));
    });
    b.run("systolic_lut_hit", "cached tile", 100, 100_000, || {
        std::hint::black_box(lut.cycles(Tile { m: 128, k: 64, n: 64 }, arr));
    });

    // --- mapper engine: exhaustive vs pruned vs pruned+hybrid --------------
    // Every mode returns the bit-identical winner; the engine's point is
    // the rounds-simulated and wall-time drop. Each (shape, mode) row is
    // also snapshotted into BENCH_mapper.json at the repo root so the
    // perf trajectory has a recorded baseline across PRs.
    let dev = presets::a100();
    let shape = Shape::simple(2048, 12288, 12288, DType::FP16);
    let decode_shape = Shape::simple(8, 12288, 12288, DType::FP16);
    let mut mapper_rows: Vec<Json> = Vec::new();
    for (tag, sh) in [("prefill_gemm", shape), ("decode_gemm", decode_shape)] {
        for (mode, budget) in [
            ("exhaustive", SearchBudget::exhaustive()),
            ("pruned", SearchBudget::default()),
            ("pruned_hybrid", SearchBudget::hybrid()),
        ] {
            let name = if tag == "prefill_gemm" {
                format!("mapper_{mode}")
            } else {
                format!("mapper_{mode}_decode")
            };
            let mlut = SystolicLut::new();
            let snap = search(&dev, &sh, budget, &mlut);
            let note =
                format!("{tag}: {}/{} rounds simulated", snap.rounds, snap.candidates);
            b.run(&name, &note, 1, 50, || {
                std::hint::black_box(search(&dev, &sh, budget, &mlut));
            });
            let (_, mean, sd, iters, _) = b.rows.last().unwrap();
            mapper_rows.push(obj(vec![
                ("bench", s(&name)),
                ("shape", s(&format!("{}x{}x{}", sh.m, sh.k, sh.n))),
                ("mode", s(mode)),
                ("candidates", num(snap.candidates as f64)),
                ("rounds_simulated", num(snap.rounds as f64)),
                ("mean_s", num(*mean)),
                ("sigma_s", num(*sd)),
                ("iters", num(*iters as f64)),
            ]));
        }
    }
    write_mapper_baseline(mapper_rows);

    let sim = Simulator::new();
    let sys = presets::system("a100x4").unwrap();
    let gpt3 = ModelConfig::gpt3_175b();
    b.run("layer_prefill_cached", "GPT-3 layer, warm mapper cache", 1, 10_000, || {
        std::hint::black_box(sim.layer(&sys, &gpt3, Phase::Prefill { batch: 8, seq: 2048 }));
    });
    b.run("layer_decode_cached", "GPT-3 layer, warm mapper cache", 1, 10_000, || {
        std::hint::black_box(sim.layer(&sys, &gpt3, Phase::Decode { batch: 8, kv_len: 3072 }));
    });

    // Paper headline: simulating GPT-3 on 4xA100 — full 96-layer request,
    // cold mapper (the paper reports 15-16 min in Python; EXPERIMENTS.md
    // §Perf tracks our number here).
    b.run("gpt3_e2e_cold_mapper", "96 layers in=2048 out=1024 b=8", 0, 3, || {
        let fresh = Simulator::new();
        std::hint::black_box(fresh.e2e_latency(&sys, &gpt3, 8, 2048, 1024, 96));
    });

    // Acceptance target for the serving simulator: 1,000 Poisson GPT-3
    // requests on an 8×A100 node must simulate in well under a minute
    // (cold mapper each iteration).
    b.run("serve_1k_gpt3_a100x8", "1000 Poisson requests, cold oracle", 0, 3, || {
        use llmcompass::serve::{self, Policy, SchedulerConfig, Slo, WorkloadSpec};
        let fresh = Simulator::pooled();
        let sys = presets::system("a100x8").unwrap();
        let cfg = SchedulerConfig::for_system(&sys, &gpt3, Policy::Fcfs);
        let reqs = serve::workload::generate(&WorkloadSpec::poisson(2.0, 1000, 42));
        std::hint::black_box(serve::serve_once(
            &fresh,
            &sys,
            &gpt3,
            &cfg,
            &reqs,
            &Slo::interactive(),
        ));
    });

    // Scheduler v2: the same trace through chunked-prefill mixed
    // iterations — more iterations than monolithic (every chunk is one),
    // so this guards the per-iteration overhead of the mixed engine.
    b.run("serve_1k_gpt3_chunked", "1000 Poisson requests, chunk 2048", 0, 3, || {
        use llmcompass::serve::{self, Policy, SchedulerConfig, ServeMode, Slo, WorkloadSpec};
        let fresh = Simulator::pooled();
        let sys = presets::system("a100x8").unwrap();
        let mut cfg = SchedulerConfig::for_system(&sys, &gpt3, Policy::Fcfs);
        cfg.mode = ServeMode::Chunked { chunk_tokens: 2048 };
        let reqs = serve::workload::generate(&WorkloadSpec::poisson(2.0, 1000, 42));
        std::hint::black_box(serve::serve_once(
            &fresh,
            &sys,
            &gpt3,
            &cfg,
            &reqs,
            &Slo::interactive(),
        ));
    });

    // Cold vs cached-mapper suite evaluation through the unified `eval`
    // API: the same three-scenario suite with a fresh Evaluator per
    // scenario (every scenario re-searches its shapes) vs one shared
    // Evaluator (later scenarios hit the mapper cache) — the
    // cross-scenario caching the `eval` layer exists to exploit.
    {
        use llmcompass::eval::{Evaluator, Scenario, Workload};
        let suite = vec![
            Scenario::new(
                "prefill-layer",
                "a100x4",
                Workload::Layer {
                    model: "gpt3-175b".into(),
                    phase: Phase::Prefill { batch: 8, seq: 2048 },
                },
            ),
            Scenario::new(
                "decode-layer",
                "a100x4",
                Workload::Layer {
                    model: "gpt3-175b".into(),
                    phase: Phase::Decode { batch: 8, kv_len: 3072 },
                },
            ),
            Scenario::new(
                "e2e-request",
                "a100x4",
                Workload::Request {
                    model: "gpt3-175b".into(),
                    batch: 8,
                    prefill: 2048,
                    decode: 1024,
                    layers: Some(12),
                },
            ),
        ];
        b.run("eval_suite_cold_mapper", "fresh Evaluator per scenario", 0, 3, || {
            for sc in &suite {
                let ev = Evaluator::new();
                std::hint::black_box(ev.evaluate(sc).unwrap());
            }
        });
        b.run("eval_suite_shared_mapper", "one Evaluator, cache shared", 0, 3, || {
            let ev = Evaluator::new();
            for sc in &suite {
                std::hint::black_box(ev.evaluate(sc).unwrap());
            }
        });
    }

    // Raw-speed pass baseline: 10k Poisson requests across a 4-replica
    // fleet, where all four replica engines resolve to the same warm
    // SharedOracle — wall time plus the oracle's deterministic
    // simulator-call count go into BENCH_serve.json. sim_calls is a pure
    // function of the request mix and bucketing (no timing noise), so the
    // snapshot doubles as a regression tripwire for the sharing itself.
    {
        use llmcompass::serve::{
            self, Balancer, FleetConfig, Policy, SchedulerConfig, Slo, WorkloadSpec,
        };
        let small = ModelConfig::gpt_small();
        let sys1 = presets::system("a100").unwrap();
        let cfg = SchedulerConfig::for_system(&sys1, &small, Policy::Fcfs);
        let fleet = FleetConfig { replicas: 4, balancer: Balancer::RoundRobin };
        let reqs = serve::workload::generate(&WorkloadSpec::poisson(120.0, 10_000, 42));
        let fresh = Simulator::pooled();
        b.run("serve_10k_fleet4", "10k Poisson requests, 4 replicas", 0, 3, || {
            std::hint::black_box(serve::serve_fleet(
                &fresh,
                &sys1,
                &small,
                &cfg,
                &fleet,
                &reqs,
                &Slo::interactive(),
            ));
        });
        let osnap = fresh.oracles.snapshot();
        let (_, mean, sd, iters, _) = b.rows.last().unwrap();
        write_serve_baseline(vec![obj(vec![
            ("bench", s("serve_10k_fleet4")),
            ("requests", num(10_000.0)),
            ("replicas", num(4.0)),
            ("mean_s", num(*mean)),
            ("sigma_s", num(*sd)),
            ("iters", num(*iters as f64)),
            ("oracle_sim_calls", num(osnap.sim_calls as f64)),
            ("oracle_hits", num(osnap.hits as f64)),
            ("oracle_misses", num(osnap.misses as f64)),
            ("oracle_decode_fits", num(osnap.decode_fits as f64)),
            ("oracle_prefill_points", num(osnap.prefill_points as f64)),
        ])]);
    }

    b.run("json_parse_device", "hardware description", 10, 100_000, || {
        let text = presets::a100().to_json().to_string_pretty();
        std::hint::black_box(llmcompass::util::json::Json::parse(&text).unwrap());
    });

    b.run("allreduce_model", "ring all-reduce eval", 100, 100_000, || {
        std::hint::black_box(llmcompass::perf::comm::all_reduce(&sys.interconnect, 1 << 24, 4));
    });

    // --- one bench per paper table/figure (quick-mode regeneration) --------
    for id in ["fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "tab4"] {
        let name = format!("experiment_{id}");
        b.run(&name, "quick-mode regeneration", 0, 5, || {
            let ctx = Ctx::new(true);
            std::hint::black_box(experiments::run(id, &ctx).unwrap());
        });
    }
    // fig5 needs artifacts; bench only when present.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        b.run("experiment_fig5", "measured validation (PJRT)", 0, 1, || {
            let ctx = Ctx::new(true);
            std::hint::black_box(experiments::run("fig5", &ctx).unwrap());
        });
    }

    b.report();
}
