//! Golden-report regression harness: every scenario in `scenarios/` is
//! evaluated through the unified `eval::Evaluator` and compared
//! field-by-field (with float tolerance) against a checked-in
//! `EvalReport` JSON under `tests/golden/`. This locks `schema_version` 1
//! and the serving metrics: a refactor that drifts any report field fails
//! with the exact path and both values, not vibes.
//!
//! Workflow:
//! * drift against an existing golden → loud failure listing every
//!   mismatched field path with expected/actual;
//! * `GOLDEN_UPDATE=1 cargo test --test integration_golden` regenerates
//!   every golden from the current code (then commit the diff);
//! * bootstrap: when `tests/golden/` holds NO goldens at all (the
//!   authoring environment had no toolchain), the first run materializes
//!   every report and passes with a "commit it" note;
//! * once any golden is checked in, the gate is armed: a scenario
//!   *without* a golden is a failure (a new scenarios/*.json cannot
//!   silently escape the gate), as is any drift.
//!
//! The harness runs a serial `Evaluator::new()` so `mapper_rounds`
//! counters are deterministic (the hybrid search's counters vary with
//! thread timing; the winners never do).

use llmcompass::eval::{self, Evaluator, SCHEMA_VERSION};
use llmcompass::util::json::{diff_with_tolerance_ignoring, Json};
use std::path::{Path, PathBuf};

/// Relative float tolerance for golden comparison: wide enough for libm
/// differences across platforms, far tighter than any modeling change.
const REL_TOL: f64 = 1e-9;
const ABS_TOL: f64 = 1e-12;

/// Report paths excluded from golden comparison: host wall-clock
/// telemetry is nondeterministic by construction (it measures this
/// machine, not the simulated one). The simulated-domain telemetry
/// counters stay under the gate.
const IGNORED_PATHS: &[&str] = &["telemetry.host"];

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn update_mode() -> bool {
    std::env::var("GOLDEN_UPDATE").map(|v| v == "1").unwrap_or(false)
}

/// CI gate: every `scenarios/*.json` file must parse as a valid
/// `Scenario` — a malformed sample is a broken deliverable even before
/// evaluation.
#[test]
fn every_scenario_file_parses() {
    let dir = scenarios_dir();
    let mut checked = 0;
    let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("read {}: {e}", dir.display()))
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    entries.sort();
    for path in entries {
        let sc = eval::Scenario::load(&path)
            .unwrap_or_else(|e| panic!("scenario {} no longer parses: {e}", path.display()));
        assert!(!sc.name.is_empty(), "{}: empty scenario name", path.display());
        checked += 1;
    }
    assert!(checked >= 10, "expected the full sample suite, found {checked} files");
}

#[test]
fn scenario_suite_matches_golden_reports() {
    let suite = eval::load_suite(&scenarios_dir()).expect("scenarios/ loads as a suite");
    std::fs::create_dir_all(golden_dir()).expect("create tests/golden");
    // Bootstrap only when NO goldens exist at all; with any golden
    // checked in, a scenario lacking one is a failure, not a skip.
    let bootstrap = std::fs::read_dir(golden_dir())
        .map(|entries| {
            !entries
                .filter_map(|e| e.ok())
                .any(|e| e.path().extension().map(|x| x == "json").unwrap_or(false))
        })
        .unwrap_or(true);
    // Serial evaluator: deterministic mapper_rounds, shared cache across
    // the suite (same winners as every other mode).
    let ev = Evaluator::new();
    let mut materialized: Vec<String> = Vec::new();
    let mut failures: Vec<String> = Vec::new();

    for sc in &suite {
        let rep = ev
            .evaluate(sc)
            .unwrap_or_else(|e| panic!("scenario `{}` failed to evaluate: {e}", sc.name));
        let actual = rep.to_json();
        assert_eq!(
            actual.get("schema_version").and_then(Json::as_u64),
            Some(SCHEMA_VERSION),
            "`{}`: report schema_version drifted",
            sc.name
        );
        let path = golden_dir().join(format!("{}.json", sc.name));
        if update_mode() || (bootstrap && !path.exists()) {
            std::fs::write(&path, actual.to_string_pretty())
                .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
            materialized.push(sc.name.clone());
            continue;
        }
        if !path.exists() {
            failures.push(format!(
                "`{}`: no golden at {} — the gate is armed (goldens exist for other \
                 scenarios); generate one with GOLDEN_UPDATE=1 and commit it\n",
                sc.name,
                path.display()
            ));
            continue;
        }
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        let expected = Json::parse(&text)
            .unwrap_or_else(|e| panic!("golden {} is not valid JSON: {e}", path.display()));
        let diffs =
            diff_with_tolerance_ignoring(&expected, &actual, REL_TOL, ABS_TOL, IGNORED_PATHS);
        if !diffs.is_empty() {
            let mut msg = format!(
                "`{}`: report drifted from {} ({} field(s)):\n",
                sc.name,
                path.display(),
                diffs.len()
            );
            for d in &diffs {
                msg.push_str(&format!("    {d}\n"));
            }
            failures.push(msg);
        }
    }

    if !materialized.is_empty() {
        println!(
            "golden: materialized {} report(s) ({}) — commit tests/golden/ to lock them",
            materialized.len(),
            materialized.join(", ")
        );
    }
    if !failures.is_empty() {
        panic!(
            "{}\n{}\nIntentional change? regenerate with \
             `GOLDEN_UPDATE=1 cargo test --test integration_golden` and commit the diff.",
            "golden-report regression:",
            failures.join("\n")
        );
    }
}

/// Checked-in goldens must stay on schema v1 — bumping the schema is a
/// deliberate act (update `SCHEMA_VERSION`, regenerate, and say so in the
/// changelog), never a drive-by.
#[test]
fn golden_reports_lock_schema_v1() {
    let dir = golden_dir();
    let Ok(entries) = std::fs::read_dir(&dir) else {
        println!("skipped: no goldens materialized yet (run the suite test first)");
        return;
    };
    let mut seen = 0;
    for e in entries.filter_map(|e| e.ok()) {
        let path = e.path();
        if path.extension().map(|x| x != "json").unwrap_or(true) {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text)
            .unwrap_or_else(|e| panic!("golden {} unparseable: {e}", path.display()));
        assert_eq!(
            j.get("schema_version").and_then(Json::as_u64),
            Some(1),
            "{} is not schema v1",
            path.display()
        );
        assert!(j.get("scenario").is_some(), "{} lacks the scenario echo", path.display());
        assert!(j.get("results").is_some(), "{} lacks results", path.display());
        seen += 1;
    }
    if seen == 0 {
        println!("skipped: no goldens materialized yet (run the suite test first)");
    }
}

/// The golden of the bursty chunked sample must carry the scheduler-v2
/// serving counters — guards the report surface, not just the values.
#[test]
fn serving_reports_carry_scheduler_v2_counters() {
    let suite = eval::load_suite(&scenarios_dir()).unwrap();
    let sc = suite
        .iter()
        .find(|sc| sc.name == "a100-bursty-chunked")
        .expect("bursty chunked sample scenario present");
    let ev = Evaluator::new();
    let rep = ev.evaluate(sc).unwrap();
    let j = rep.to_json();
    let stats = j
        .get("results")
        .and_then(|r| r.get("serving"))
        .and_then(|s| s.get("stats"))
        .expect("serving stats present");
    for key in [
        "mixed_iterations",
        "mixed_busy_s",
        "preemptions",
        "preempted_requests",
        "recompute_tokens",
        "transfer_total_s",
        "handoff_wait_s",
        "handoff_stall_s",
        "prefill_peak_kv_tokens",
        "faults_injected",
        "requests_lost",
        "requests_retried",
        "requests_shed",
        "retry_tokens_recomputed",
        "fault_downtime_s",
        "availability",
    ] {
        assert!(stats.get(key).is_some(), "serving stats lost `{key}`");
    }
    let summary = j
        .get("results")
        .and_then(|r| r.get("serving"))
        .and_then(|s| s.get("summary"))
        .unwrap();
    assert!(summary.get("ttft_mean_s").is_some());
    assert!(summary.get("tpot_mean_s").is_some());
    assert!(summary.get("faulted_requests").is_some());
    assert!(summary.get("ttft_p99_faulted_s").is_some());
    assert!(summary.get("tpot_p99_faulted_s").is_some());
}
