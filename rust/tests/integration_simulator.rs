//! Cross-module integration: the full simulator pipeline (hardware →
//! mapper → graph → e2e) reproducing the paper's architectural
//! implications ①–⑤ end to end.

use llmcompass::graph::inference::{max_batch, Simulator};
use llmcompass::graph::layer::{layer_min_bytes, Phase};
use llmcompass::graph::ModelConfig;
use llmcompass::hardware::{presets, InterconnectSpec, SystemSpec};

fn tp4(dev: llmcompass::hardware::DeviceSpec) -> SystemSpec {
    SystemSpec { device: dev, device_count: 4, interconnect: InterconnectSpec::nvlink_like(600e9) }
}

#[test]
fn implication_1_compute_helps_prefill_not_decode() {
    // Design A has 1/4 of design B's compute; same memory system.
    let sim = Simulator::new();
    let m = ModelConfig::gpt3_175b();
    let a = tp4(presets::design('A').unwrap());
    let b = tp4(presets::design('B').unwrap());
    let pre_a = sim.layer(&a, &m, Phase::Prefill { batch: 8, seq: 2048 }).total_s;
    let pre_b = sim.layer(&b, &m, Phase::Prefill { batch: 8, seq: 2048 }).total_s;
    let dec_a = sim.layer(&a, &m, Phase::Decode { batch: 8, kv_len: 3072 }).total_s;
    let dec_b = sim.layer(&b, &m, Phase::Decode { batch: 8, kv_len: 3072 }).total_s;
    // Paper: 3.25x prefill gap, ~0.1% decode gap.
    let prefill_ratio = pre_a / pre_b;
    assert!(
        (2.0..5.0).contains(&prefill_ratio),
        "prefill A/B = {prefill_ratio:.2} (paper 3.25)"
    );
    let decode_ratio = dec_a / dec_b;
    assert!(
        (0.95..1.15).contains(&decode_ratio),
        "decode A/B = {decode_ratio:.3} (paper ~1.001)"
    );
}

#[test]
fn implication_3_decode_bandwidth_sensitivity() {
    // 800 → 2000 GB/s: paper sees 1.88x decode speedup, 14.3% prefill.
    let sim = Simulator::new();
    let m = ModelConfig::gpt3_175b();
    let mk = |bw: f64| {
        let mut d = presets::a100();
        d.name = format!("a100bw{bw}");
        d.memory.bandwidth_bytes_per_s = bw;
        tp4(d)
    };
    let lo = mk(800e9);
    let hi = mk(2000e9);
    let dec_speedup = sim.layer(&lo, &m, Phase::Decode { batch: 8, kv_len: 3072 }).total_s
        / sim.layer(&hi, &m, Phase::Decode { batch: 8, kv_len: 3072 }).total_s;
    let pre_speedup = sim.layer(&lo, &m, Phase::Prefill { batch: 8, seq: 2048 }).total_s
        / sim.layer(&hi, &m, Phase::Prefill { batch: 8, seq: 2048 }).total_s;
    assert!((1.5..2.6).contains(&dec_speedup), "decode speedup {dec_speedup:.2} (paper 1.88)");
    assert!(pre_speedup < 1.4, "prefill speedup {pre_speedup:.2} (paper 1.17)");
    assert!(dec_speedup > pre_speedup, "implication ③ ordering");
}

#[test]
fn implication_4_buffers_help_prefill_not_decode() {
    let sim = Simulator::new();
    let m = ModelConfig::gpt3_175b();
    let mk = |kb: u64| {
        let mut d = presets::a100();
        d.name = format!("a100l1{kb}");
        d.core.local_buffer_bytes = kb * 1024;
        tp4(d)
    };
    let small = mk(64);
    let big = mk(192);
    let pre_gain = sim.layer(&small, &m, Phase::Prefill { batch: 8, seq: 2048 }).total_s
        / sim.layer(&big, &m, Phase::Prefill { batch: 8, seq: 2048 }).total_s;
    let dec_gain = sim.layer(&small, &m, Phase::Decode { batch: 8, kv_len: 3072 }).total_s
        / sim.layer(&big, &m, Phase::Decode { batch: 8, kv_len: 3072 }).total_s;
    assert!(pre_gain > 1.05, "prefill gain {pre_gain:.3} (paper 1.22 at 64→192KB)");
    assert!((0.98..1.05).contains(&dec_gain), "decode flat, got {dec_gain:.3}");
}

#[test]
fn latency_design_matches_ga100_decode_but_lags_prefill() {
    // §V-A: identical decode; prefill suffers (that's the 0.80 corner of
    // Fig. 10).
    let sim = Simulator::new();
    let m = ModelConfig::gpt3_175b();
    let ga = tp4(presets::ga100());
    let lat = tp4(presets::latency_oriented());
    let dec_ratio = sim.layer(&lat, &m, Phase::Decode { batch: 16, kv_len: 2048 }).total_s
        / sim.layer(&ga, &m, Phase::Decode { batch: 16, kv_len: 2048 }).total_s;
    assert!((0.99..1.06).contains(&dec_ratio), "decode ratio {dec_ratio:.3}");
    let pre_ratio = sim.layer(&lat, &m, Phase::Prefill { batch: 16, seq: 2048 }).total_s
        / sim.layer(&ga, &m, Phase::Prefill { batch: 16, seq: 2048 }).total_s;
    assert!(pre_ratio > 1.3, "prefill should lag: {pre_ratio:.2}x (paper ~1.9x worst-case)");
}

#[test]
fn decode_layer_io_dominated_on_a100() {
    // Decode latency ≈ weight+KV traffic / bandwidth (IO-bound claim).
    let sim = Simulator::new();
    let m = ModelConfig::gpt3_175b();
    let sys = tp4(presets::a100());
    let lat = sim.layer(&sys, &m, Phase::Decode { batch: 8, kv_len: 3072 }).total_s;
    let io = layer_min_bytes(&m, Phase::Decode { batch: 8, kv_len: 3072 }, 4)
        / sys.device.memory.bandwidth_bytes_per_s;
    assert!(lat / io < 3.0, "decode at {:.2}x of pure IO bound", lat / io);
    assert!(lat >= io);
}

#[test]
fn throughput_design_trades_latency_for_batch() {
    let sim = Simulator::new();
    let m = ModelConfig::gpt3_175b();
    // Batch capacity: >12x GA100 (paper §V-B).
    let b_ga = max_batch(&presets::ga100(), &m, 12, 1, 4096);
    let b_thr = max_batch(&presets::throughput_oriented(), &m, 12, 1, 4096);
    assert!(b_thr > 12 * b_ga, "{b_thr} vs {b_ga}");
    // Throughput wins at PP=8 even with half the bandwidth.
    let thr_sys = SystemSpec {
        device: presets::throughput_oriented(),
        device_count: 8,
        interconnect: InterconnectSpec::nvlink_like(600e9),
    };
    let ga_sys = SystemSpec {
        device: presets::ga100(),
        device_count: 8,
        interconnect: InterconnectSpec::nvlink_like(600e9),
    };
    let (tok_thr, _, stage_thr) = sim.pipeline_throughput(&thr_sys, &m, 512, 512);
    let (tok_ga, _, stage_ga) = sim.pipeline_throughput(&ga_sys, &m, 512, 512);
    assert!(tok_thr / tok_ga > 1.0, "normalized throughput {:.2}", tok_thr / tok_ga);
    // And the latency trade-off exists (paper: 9.21x worse).
    assert!(stage_thr > 2.0 * stage_ga, "latency should degrade materially");
}

#[test]
fn mapper_round_count_order_of_magnitude() {
    // The paper reports 26,400 mapper rounds for a full GPT-3 inference
    // sim. Our exhaustive search budget should land within the same
    // order: a full e2e run stays under ~300k rounds and above ~1k. The
    // default (pruned) engine must reach the identical timings while
    // simulating well under half of those rounds.
    use llmcompass::perf::mapper::{Mapper, SearchBudget};
    let exhaustive = Simulator::with_mapper(Mapper::new(SearchBudget::exhaustive()));
    let m = ModelConfig::gpt3_175b();
    let sys = tp4(presets::a100());
    let t_ex = exhaustive.e2e_latency(&sys, &m, 8, 2048, 1024, 96);
    let rounds = exhaustive.mapper.total_rounds();
    assert!(
        (1_000..400_000).contains(&rounds),
        "mapper rounds {rounds} out of expected range"
    );
    let pruned = Simulator::new();
    let t_pr = pruned.e2e_latency(&sys, &m, 8, 2048, 1024, 96);
    assert_eq!(t_pr.to_bits(), t_ex.to_bits(), "pruned e2e latency drifted");
    // Decode-class GEMMs sit on their IO floor, so most of their
    // candidates survive the bound; the 2x criterion applies to the
    // prefill-class search (perf::mapper tests). Across a whole e2e mix
    // the engine must still shave ≥ 10%.
    assert!(
        pruned.mapper.total_rounds() * 10 <= rounds * 9,
        "pruning only cut rounds {rounds} → {}",
        pruned.mapper.total_rounds()
    );
}

#[test]
fn tensor_parallelism_scales_prefill() {
    let sim = Simulator::new();
    let m = ModelConfig::gpt3_175b();
    let t1 = sim
        .layer(&presets::system("a100").unwrap(), &m, Phase::Prefill { batch: 8, seq: 2048 })
        .total_s;
    let t4 = sim.layer(&tp4(presets::a100()), &m, Phase::Prefill { batch: 8, seq: 2048 }).total_s;
    // 4-way TP should cut compute ~4x minus all-reduce overhead.
    let speedup = t1 / t4;
    assert!((2.5..4.2).contains(&speedup), "TP4 prefill speedup {speedup:.2}");
}

#[test]
fn published_roofline_shape_fixtures() {
    // Paper §III-C: "for a Matmul with M=64 and N=K=12288, AMD MI210 is
    // less than 25% of its roofline performance while a NVIDIA A100 can
    // achieve 50%" — check the simulator respects who-is-closer-to-
    // roofline ordering for that exact shape, and that a large square
    // GEMM on A100 lands at a credible fraction of peak.
    use llmcompass::hardware::DType;
    use llmcompass::perf::Op;
    let sim = Simulator::new();
    let narrow = |dev: llmcompass::hardware::DeviceSpec| {
        let sys = SystemSpec {
            device: dev,
            device_count: 1,
            interconnect: InterconnectSpec::nvlink_like(600e9),
        };
        sim.op_latency(
            &sys,
            &Op::Matmul { b: 1, m: 64, k: 12288, n: 12288, dtype: DType::FP16, batched_b: false },
        )
        .roofline_fraction()
    };
    let a100_frac = narrow(presets::a100());
    let mi210_frac = narrow(presets::mi210());
    // The narrow GEMM is IO-bound on both; what distinguishes them in the
    // paper is how far from *some* bound each lands. Require the same
    // ordering: A100 ≥ MI210, both in a physical (0, 1] band.
    assert!(a100_frac > 0.0 && a100_frac <= 1.0);
    assert!(mi210_frac > 0.0 && mi210_frac <= 1.0);
    assert!(
        a100_frac >= mi210_frac * 0.95,
        "A100 {a100_frac:.2} should not trail MI210 {mi210_frac:.2} (paper: 50% vs <25%)"
    );

    // Large square GEMM on A100: paper-scale kernels achieve >=50% of the
    // 312 TFLOPS tensor peak; our mapper should land in [0.35, 1.0].
    let sys = SystemSpec {
        device: presets::a100(),
        device_count: 1,
        interconnect: InterconnectSpec::nvlink_like(600e9),
    };
    let big = sim.op_latency(
        &sys,
        &Op::Matmul { b: 1, m: 4096, k: 4096, n: 4096, dtype: DType::FP16, batched_b: false },
    );
    assert!(
        big.roofline_fraction() > 0.35,
        "big GEMM at {:.2} of roofline",
        big.roofline_fraction()
    );
}

#[test]
fn mqa_variant_improves_serving_metrics_end_to_end() {
    // §II-A variant support, through the full simulator: MQA cuts decode
    // latency and KV footprint vs MHA on identical hardware.
    let sim = Simulator::new();
    let sys = tp4(presets::a100());
    let mha = ModelConfig::gpt3_175b();
    let mqa = ModelConfig::gpt3_palm_style();
    let d_mha = sim.layer(&sys, &mha, Phase::Decode { batch: 8, kv_len: 3072 }).total_s;
    let d_mqa = sim.layer(&sys, &mqa, Phase::Decode { batch: 8, kv_len: 3072 }).total_s;
    assert!(d_mqa < d_mha, "MQA decode {d_mqa} should beat MHA {d_mha}");
    assert!(mqa.kv_bytes_per_token_per_layer() * 96 == mha.kv_bytes_per_token_per_layer());
}
