//! Integration: the PJRT runtime executing real AOT artifacts.
//!
//! Requires `make artifacts` (skipped with a note otherwise — CI runs
//! `make test`, which builds them first).

use llmcompass::coordinator::{queue, Coordinator};
use llmcompass::runtime::{HostTensor, Runtime};
use std::path::Path;

fn artifact_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipped: PJRT integration test needs artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn matmul_artifact_computes_correctly() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(dir).unwrap();
    // 256x256x256 f32 matmul against a host-side reference.
    let n = 256usize;
    let a: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32 - 3.0) * 0.25).collect();
    let b: Vec<f32> = (0..n * n).map(|i| ((i % 5) as f32 - 2.0) * 0.5).collect();
    let out = rt
        .run(
            "matmul_256x256x256",
            &[
                HostTensor::F32(a.clone(), vec![n, n]),
                HostTensor::F32(b.clone(), vec![n, n]),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 1);
    let got = out[0].f32().unwrap();
    assert_eq!(out[0].shape(), &[n, n]);
    // Spot-check a few entries against a naive reference.
    for &(r, c) in &[(0usize, 0usize), (1, 2), (100, 200), (255, 255)] {
        let mut want = 0.0f64;
        for k in 0..n {
            want += a[r * n + k] as f64 * b[k * n + c] as f64;
        }
        let g = got[r * n + c] as f64;
        assert!(
            (g - want).abs() < 1e-2 * want.abs().max(1.0),
            "C[{r},{c}] = {g} vs {want}"
        );
    }
}

#[test]
fn softmax_artifact_rows_sum_to_one() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(dir).unwrap();
    let (m, n) = (64usize, 512usize);
    let x: Vec<f32> = (0..m * n).map(|i| ((i % 11) as f32 - 5.0) * 0.3).collect();
    let out = rt.run("softmax_64x512", &[HostTensor::F32(x, vec![m, n])]).unwrap();
    let got = out[0].f32().unwrap();
    for r in 0..m {
        let s: f32 = got[r * n..(r + 1) * n].iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
        assert!(got[r * n..(r + 1) * n].iter().all(|&p| p >= 0.0));
    }
}

#[test]
fn init_prefill_decode_roundtrip() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(dir).unwrap();
    let meta = rt.manifest().model.clone();
    let params = rt.run("init", &[]).unwrap().remove(0);
    assert_eq!(params.shape(), &[meta.n_params as usize]);
    let vals = params.f32().unwrap();
    assert!(vals.iter().all(|v| v.is_finite()));
    // Parameters should be mostly non-zero (random init) but contain the
    // zero-initialized biases.
    let nonzero = vals.iter().filter(|&&v| v != 0.0).count();
    assert!(nonzero as f64 > 0.9 * vals.len() as f64 * 0.5);

    // Prefill a b=4, s=64 prompt.
    let (b, s) = (4usize, 64usize);
    let tokens: Vec<i32> = (0..b * s).map(|i| (i % meta.vocab as usize) as i32).collect();
    let mut out = rt
        .run(
            "prefill_b4_s64",
            &[params.clone(), HostTensor::I32(tokens, vec![b, s])],
        )
        .unwrap();
    assert_eq!(out.len(), 3);
    let logits = out.remove(0);
    assert_eq!(logits.shape(), &[b, meta.vocab as usize]);
    let kv_k = out.remove(0);
    let kv_v = out.remove(0);
    assert_eq!(
        kv_k.shape(),
        &[meta.layers as usize, b, meta.max_seq as usize, meta.d_model as usize]
    );

    // One decode step at pos=64.
    let next = llmcompass::coordinator::argmax_tokens(&logits).unwrap();
    let out2 = rt
        .run(
            "decode_b4",
            &[
                params,
                HostTensor::I32(next, vec![b]),
                kv_k,
                kv_v,
                HostTensor::scalar_i32(s as i32),
            ],
        )
        .unwrap();
    assert_eq!(out2.len(), 3);
    assert_eq!(out2[0].shape(), &[b, meta.vocab as usize]);
    assert!(out2[0].f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn coordinator_serves_batch_and_reports() {
    let Some(dir) = artifact_dir() else { return };
    let mut coord = Coordinator::new(dir).unwrap();
    let vocab = coord.vocab() as i32;
    let reqs = queue::synthetic_trace(5, vocab, 32, 4, 42);
    let report = coord.serve(&reqs).unwrap();
    assert_eq!(report.completions.len(), 5);
    for (c, r) in report.completions.iter().zip(&reqs) {
        assert_eq!(c.id, r.id);
        assert_eq!(c.tokens.len(), r.n_tokens.min(64));
        assert!(c.tokens.iter().all(|&t| t >= 0 && t < vocab));
        assert!(c.latency_s > 0.0);
    }
    assert!(report.tokens_per_s() > 0.0);
    assert!(report.tokens_generated >= 5);

    // Determinism: the same trace generates the same tokens.
    let report2 = coord.serve(&reqs).unwrap();
    for (a, b) in report.completions.iter().zip(&report2.completions) {
        assert_eq!(a.tokens, b.tokens, "request {} tokens differ across runs", a.id);
    }
}
