//! Property tests for scheduler-v2 invariants, via `util::quick`:
//!
//! 1. KV occupancy never exceeds the configured capacity (per pool in
//!    disaggregated mode), in every mode × preemption combination;
//! 2. every admitted request either completes or is counted preempted —
//!    and since the simulator runs traces to completion, *everything*
//!    completes, preempted or not, with a sane timeline;
//! 3. total generated tokens are conserved across
//!    monolithic/chunked/disaggregated executions of the same trace;
//! 4. under any seeded random [`FaultSpec`], request accounting conserves
//!    (`completed + lost + shed == submitted`), retry counters stay
//!    bounded, and KV occupancy still respects capacity;
//! 5. an inert (zero-fault) spec reproduces the no-spec `ServeReport`
//!    byte-for-byte in every mode;
//! 6. a 1-replica fleet reproduces the single-engine `ServeReport`
//!    byte-for-byte in every mode (with and without faults);
//! 7. multi-replica fleets conserve requests
//!    (`completed + lost + shed == submitted`) under every balancer and
//!    replica count, and every replica's KV peak respects the per-engine
//!    budget;
//! 8. the shared latency-oracle cache is invisible to results: a fleet
//!    run whose replicas share one warm `SharedOracle` is byte-identical
//!    to the same run with sharing disabled (every engine gets a private
//!    cold oracle), in every mode × replica count × faults combination.
//!
//! One shared `Simulator` keeps mapper searches cached across trials, so
//! hundreds of random schedules cost oracle-cache lookups, not searches.

use llmcompass::graph::inference::Simulator;
use llmcompass::graph::ModelConfig;
use llmcompass::hardware::presets;
use llmcompass::serve::{
    self, scheduler, FaultEvent, FaultKind, FaultSpec, FaultTarget, Policy, Preemption,
    RecoveryPolicy, Request, SchedulerConfig, ServeMode,
};
use llmcompass::util::quick::{forall, Gen};

/// Random trace whose largest request is bounded so capacity can be drawn
/// relative to it.
fn gen_trace(g: &mut Gen, n_max: usize) -> Vec<Request> {
    let n = g.usize(3, n_max);
    let mut t = 0.0;
    (0..n as u64)
        .map(|id| {
            t += g.f64(0.0, 0.05);
            Request {
                id,
                arrival_s: t,
                prompt_tokens: g.u64(16, 600),
                output_tokens: g.u64(1, 120),
            }
        })
        .collect()
}

fn gen_mode(g: &mut Gen, device_count: u64) -> ServeMode {
    match g.u64(0, if device_count >= 2 { 2 } else { 1 }) {
        0 => ServeMode::Monolithic,
        1 => ServeMode::Chunked { chunk_tokens: g.u64(48, 1024) },
        _ => ServeMode::Disaggregated {
            prefill_devices: g.u64(1, device_count - 1),
            transfer_base_s: g.f64(0.0, 0.01),
        },
    }
}

fn gen_cfg(g: &mut Gen, sys_devices: u64, trace: &[Request]) -> SchedulerConfig {
    let max_total = trace.iter().map(Request::total_tokens).max().unwrap();
    let mode = gen_mode(g, sys_devices);
    // Capacity between "tight" and "roomy", always ≥ what `validate`
    // demands: the proportional pool split reserves the smallest share
    // for a 1-device pool (1/devices), so scale past its inverse.
    let headroom = g.u64(2 * sys_devices.max(1), 8 * sys_devices.max(1));
    // Exercise the bounded handoff queue too: explicit tight/roomy bounds
    // or the derived default.
    let handoff_capacity = match g.u64(0, 2) {
        0 => None,
        _ => Some(g.u64(1, 16)),
    };
    SchedulerConfig {
        max_batch: g.u64(1, 24),
        kv_capacity_tokens: max_total * headroom,
        policy: *g.pick(&[Policy::Fcfs, Policy::ShortestPromptFirst]),
        max_prefill_batch: g.u64(1, 8),
        mode,
        preemption: *g.pick(&[Preemption::Conservative, Preemption::Evict]),
        handoff_capacity,
        faults: None,
    }
}

/// Random fault schedule: up to a handful of explicit events of every
/// kind/target, an optional aggressive MTBF process, and a recovery
/// policy with every pressure knob randomly armed. Durations and times
/// are sized to the sub-2-second traces `gen_trace` produces so windows
/// actually overlap live work.
fn gen_fault_spec(g: &mut Gen) -> FaultSpec {
    let n = g.usize(0, 4);
    let events = (0..n)
        .map(|_| FaultEvent {
            kind: match g.u64(0, 3) {
                0 => FaultKind::Crash,
                1 => FaultKind::Drain,
                2 => FaultKind::Slowdown { multiplier: g.f64(1.0, 6.0) },
                _ => FaultKind::LinkDegrade { factor: g.f64(1.0, 8.0) },
            },
            at_s: g.f64(0.0, 1.5),
            duration_s: g.f64(0.0, 1.0),
            target: *g.pick(&[FaultTarget::All, FaultTarget::Prefill, FaultTarget::Decode]),
        })
        .collect();
    FaultSpec {
        seed: g.u64(0, 1 << 20),
        events,
        mtbf_s: if g.u64(0, 2) == 0 { Some(g.f64(0.2, 2.0)) } else { None },
        mttr_s: g.f64(0.05, 0.5),
        correlated_fraction: if g.u64(0, 2) == 0 { g.f64(0.0, 1.0) } else { 0.0 },
        recovery: RecoveryPolicy {
            max_retries: g.u64(0, 3),
            retry_backoff_s: g.f64(0.0, 0.3),
            request_timeout_s: if g.u64(0, 2) == 0 { Some(g.f64(0.5, 5.0)) } else { None },
            shed_queue_depth: if g.u64(0, 2) == 0 { Some(g.u64(1, 12)) } else { None },
            degraded_chunk_tokens: if g.u64(0, 2) == 0 { Some(g.u64(32, 256)) } else { None },
        },
    }
}

#[test]
fn kv_occupancy_never_exceeds_capacity() {
    let sim = Simulator::new();
    let sys = presets::system("a100x4").unwrap();
    let model = ModelConfig::gpt_small();
    forall("kv occupancy ≤ capacity", 40, |g| {
        let trace = gen_trace(g, 24);
        let cfg = gen_cfg(g, sys.device_count, &trace);
        let (pre_cap, dec_cap) = cfg.pool_budgets(sys.device_count);
        let (_, stats) = scheduler::simulate(&sim, &sys, &model, &cfg, &trace);
        let ok = stats.peak_kv_tokens <= dec_cap && stats.prefill_peak_kv_tokens <= pre_cap;
        (
            format!(
                "mode {:?} preempt {:?} cap {} → peak {} (≤ {}), prefill peak {} (≤ {})",
                cfg.mode,
                cfg.preemption,
                cfg.kv_capacity_tokens,
                stats.peak_kv_tokens,
                dec_cap,
                stats.prefill_peak_kv_tokens,
                pre_cap
            ),
            ok,
        )
    });
}

#[test]
fn every_admitted_request_completes_or_is_counted_preempted() {
    let sim = Simulator::new();
    let sys = presets::system("a100x4").unwrap();
    let model = ModelConfig::gpt_small();
    forall("complete or counted preempted", 40, |g| {
        let trace = gen_trace(g, 24);
        let cfg = gen_cfg(g, sys.device_count, &trace);
        let (metrics, stats) = scheduler::simulate(&sim, &sys, &model, &cfg, &trace);
        let all_finish = metrics.iter().all(|m| {
            m.first_token_s.is_finite()
                && m.finish_s.is_finite()
                && m.first_token_s > m.arrival_s
                && m.finish_s >= m.first_token_s
        });
        let counters_sane = stats.preempted_requests <= trace.len() as u64
            && stats.preempted_requests <= stats.preemptions
            && (cfg.preemption == Preemption::Evict || stats.preemptions == 0)
            && (stats.preemptions == 0) == (stats.recompute_tokens == 0 && stats.preempted_requests == 0);
        (
            format!(
                "mode {:?} preempt {:?}: finished {}, preemptions {} over {} requests",
                cfg.mode,
                cfg.preemption,
                all_finish,
                stats.preemptions,
                stats.preempted_requests
            ),
            all_finish && counters_sane,
        )
    });
}

#[test]
fn generated_tokens_conserved_across_modes_on_the_same_trace() {
    let sim = Simulator::new();
    let sys = presets::system("a100x4").unwrap();
    let model = ModelConfig::gpt_small();
    forall("token conservation across modes", 25, |g| {
        let trace = gen_trace(g, 16);
        let expected: u64 = trace.iter().map(|r| r.output_tokens).sum();
        let preemption = *g.pick(&[Preemption::Conservative, Preemption::Evict]);
        let chunk = g.u64(48, 1024);
        let prefill_devices = g.u64(1, sys.device_count - 1);
        let max_total = trace.iter().map(Request::total_tokens).max().unwrap();
        let headroom = g.u64(2 * sys.device_count, 6 * sys.device_count);
        let totals: Vec<u64> = [
            ServeMode::Monolithic,
            ServeMode::Chunked { chunk_tokens: chunk },
            ServeMode::Disaggregated { prefill_devices, transfer_base_s: 1e-3 },
        ]
        .into_iter()
        .map(|mode| {
            let cfg = SchedulerConfig {
                max_batch: 12,
                kv_capacity_tokens: max_total * headroom,
                policy: Policy::Fcfs,
                max_prefill_batch: 4,
                mode,
                preemption,
                handoff_capacity: None,
                faults: None,
            };
            let (metrics, stats) = scheduler::simulate(&sim, &sys, &model, &cfg, &trace);
            let summary =
                serve::metrics::summarize(&metrics, &serve::Slo::relaxed(), stats.makespan_s);
            summary.output_tokens
        })
        .collect();
        let ok = totals.iter().all(|&t| t == expected);
        (format!("expected {expected}, per mode {totals:?}"), ok)
    });
}

#[test]
fn fault_accounting_conserves_requests_under_any_spec() {
    let sim = Simulator::new();
    let sys = presets::system("a100x4").unwrap();
    let model = ModelConfig::gpt_small();
    forall("completed + lost + shed == submitted", 40, |g| {
        let trace = gen_trace(g, 24);
        let mut cfg = gen_cfg(g, sys.device_count, &trace);
        cfg.faults = Some(std::sync::Arc::new(gen_fault_spec(g)));
        let (pre_cap, dec_cap) = cfg.pool_budgets(sys.device_count);
        let (metrics, stats) = scheduler::simulate(&sim, &sys, &model, &cfg, &trace);
        let submitted = trace.len() as u64;
        let conserved =
            metrics.len() as u64 + stats.requests_lost + stats.requests_shed == submitted;
        // Survivors have sane timelines; crash victims and shed arrivals
        // are filtered out of the returned metrics entirely.
        let survivors_sane = metrics.iter().all(|m| {
            m.first_token_s.is_finite()
                && m.finish_s >= m.first_token_s
                && m.first_token_s > m.arrival_s
        });
        let counters_bounded = stats.requests_retried <= submitted
            && stats.requests_lost <= submitted
            && stats.requests_shed <= submitted
            && (stats.requests_retried > 0 || stats.retry_tokens_recomputed == 0)
            && stats.fault_downtime_s <= stats.makespan_s + 1e-9
            && (0.0..=1.0).contains(&stats.availability);
        let kv_ok = stats.peak_kv_tokens <= dec_cap && stats.prefill_peak_kv_tokens <= pre_cap;
        (
            format!(
                "mode {:?}: {} completed + {} lost + {} shed of {submitted}, retried {}, \
                 availability {:.4}, kv {}/{} (≤ {}/{})",
                cfg.mode,
                metrics.len(),
                stats.requests_lost,
                stats.requests_shed,
                stats.requests_retried,
                stats.availability,
                stats.prefill_peak_kv_tokens,
                stats.peak_kv_tokens,
                pre_cap,
                dec_cap
            ),
            conserved && survivors_sane && counters_bounded && kv_ok,
        )
    });
}

#[test]
fn single_replica_fleet_reproduces_serve_once_byte_for_byte() {
    let sim = Simulator::new();
    let sys = presets::system("a100x4").unwrap();
    let model = ModelConfig::gpt_small();
    forall("1-replica fleet ⇒ byte-identical report", 15, |g| {
        let trace = gen_trace(g, 16);
        let mut cfg = gen_cfg(g, sys.device_count, &trace);
        if g.u64(0, 1) == 0 {
            cfg.faults = Some(std::sync::Arc::new(gen_fault_spec(g)));
        }
        let slo = serve::Slo::relaxed();
        let (base, _) = serve::serve_once(&sim, &sys, &model, &cfg, &trace, &slo);
        let (fleet, _) = serve::serve_fleet(
            &sim,
            &sys,
            &model,
            &cfg,
            &serve::FleetConfig::single(),
            &trace,
            &slo,
        );
        let (a, b) = (base.to_json().to_string_pretty(), fleet.to_json().to_string_pretty());
        (
            format!(
                "mode {:?} faults {}: single-engine report {} 1-replica fleet report",
                cfg.mode,
                cfg.faults.is_some(),
                if a == b { "==" } else { "!=" },
            ),
            a == b,
        )
    });
}

#[test]
fn fleet_conserves_requests_and_respects_per_replica_kv() {
    let sim = Simulator::new();
    let sys = presets::system("a100x4").unwrap();
    let model = ModelConfig::gpt_small();
    forall("fleet conservation + per-replica KV", 20, |g| {
        let trace = gen_trace(g, 24);
        let mut cfg = gen_cfg(g, sys.device_count, &trace);
        if g.u64(0, 1) == 0 {
            cfg.faults = Some(std::sync::Arc::new(gen_fault_spec(g)));
        }
        let fleet = serve::FleetConfig {
            replicas: g.u64(2, 4),
            balancer: *g.pick(&[
                serve::Balancer::RoundRobin,
                serve::Balancer::LeastKvPressure,
                serve::Balancer::SessionAffinity,
            ]),
        };
        let (pre_cap, dec_cap) = cfg.pool_budgets(sys.device_count);
        let (report, metrics) =
            serve::serve_fleet(&sim, &sys, &model, &cfg, &fleet, &trace, &serve::Slo::relaxed());
        let stats = &report.stats;
        let submitted = trace.len() as u64;
        let conserved =
            metrics.len() as u64 + stats.requests_lost + stats.requests_shed == submitted;
        let per_replica = report.replica_stats.len() == fleet.replicas as usize;
        // The fleet's per-engine KV budgets are identical across replicas.
        let kv_ok = report
            .replica_stats
            .iter()
            .all(|rs| rs.peak_kv_tokens <= dec_cap && rs.prefill_peak_kv_tokens <= pre_cap);
        let availability_ok = (0.0..=1.0).contains(&stats.availability);
        (
            format!(
                "{:?} x{}: {} completed + {} lost + {} shed of {submitted}, \
                 replica KV peaks {:?} (≤ {dec_cap}), availability {:.4}",
                fleet.balancer,
                fleet.replicas,
                metrics.len(),
                stats.requests_lost,
                stats.requests_shed,
                report.replica_stats.iter().map(|rs| rs.peak_kv_tokens).collect::<Vec<_>>(),
                stats.availability
            ),
            conserved && per_replica && kv_ok && availability_ok,
        )
    });
}

#[test]
fn inert_fault_spec_reproduces_the_no_spec_report_byte_for_byte() {
    let sim = Simulator::new();
    let sys = presets::system("a100x4").unwrap();
    let model = ModelConfig::gpt_small();
    forall("zero-fault spec ⇒ byte-identical report", 15, |g| {
        let trace = gen_trace(g, 16);
        let cfg = gen_cfg(g, sys.device_count, &trace);
        let mut faulted = cfg.clone();
        faulted.faults = Some(std::sync::Arc::new(FaultSpec::none()));
        let slo = serve::Slo::relaxed();
        let (base, _) = serve::serve_once(&sim, &sys, &model, &cfg, &trace, &slo);
        let (inert, _) = serve::serve_once(&sim, &sys, &model, &faulted, &trace, &slo);
        let (a, b) =
            (base.to_json().to_string_pretty(), inert.to_json().to_string_pretty());
        (
            format!(
                "mode {:?}: no-spec report {} inert-spec report ({} bytes)",
                cfg.mode,
                if a == b { "==" } else { "!=" },
                a.len()
            ),
            a == b && inert.stats.faults_injected == 0 && inert.stats.availability == 1.0,
        )
    });
}

#[test]
fn shared_oracle_fleet_reproduces_private_oracle_run_byte_for_byte() {
    // The raw-speed pass's correctness lock: sharing one warm oracle
    // across fleet replicas must not change a single byte of the report
    // relative to every engine simulating with its own cold oracle.
    // Oracle values are pure functions of (hardware, model, bucket), so
    // any divergence here means the cache leaked state between keys.
    let model = ModelConfig::gpt_small();
    let sys = presets::system("a100x4").unwrap();
    forall("shared oracle ⇒ byte-identical fleet report", 12, |g| {
        let trace = gen_trace(g, 16);
        let mut cfg = gen_cfg(g, sys.device_count, &trace);
        if g.u64(0, 1) == 0 {
            cfg.faults = Some(std::sync::Arc::new(gen_fault_spec(g)));
        }
        let fleet = serve::FleetConfig {
            replicas: g.u64(2, 4),
            balancer: serve::Balancer::RoundRobin,
        };
        let slo = serve::Slo::relaxed();
        // Fresh simulators on both sides so neither run sees warm state
        // the other did not; only the sharing policy differs.
        let shared_sim = Simulator::new();
        let (shared, _) =
            serve::serve_fleet(&shared_sim, &sys, &model, &cfg, &fleet, &trace, &slo);
        let private_sim = Simulator::new();
        private_sim.oracles.set_shared(false);
        let (private_, _) =
            serve::serve_fleet(&private_sim, &sys, &model, &cfg, &fleet, &trace, &slo);
        let (a, b) = (shared.to_json().to_string_pretty(), private_.to_json().to_string_pretty());
        // With sharing on, replicas hit the same warm buckets; with it
        // off, every engine re-simulates its own — so the private run can
        // only ever cost more simulator calls, never fewer.
        let calls_ok = private_sim.oracles.snapshot().sim_calls
            >= shared_sim.oracles.snapshot().sim_calls;
        (
            format!(
                "mode {:?} x{} faults {}: shared report {} private report \
                 (sim_calls shared {} vs private {})",
                cfg.mode,
                fleet.replicas,
                cfg.faults.is_some(),
                if a == b { "==" } else { "!=" },
                shared_sim.oracles.snapshot().sim_calls,
                private_sim.oracles.snapshot().sim_calls,
            ),
            a == b && calls_ok,
        )
    });
}
