//! Integration tests for the unified `eval` layer: the shipped
//! `scenarios/` suite evaluates end to end, reports keep the stable v1
//! schema, scenarios survive JSON round trips, and a shared evaluator
//! performs fewer mapper searches than independent ones — the acceptance
//! criteria of the scenario API.

use llmcompass::eval::{self, Evaluator, Scenario, SCHEMA_VERSION};
use llmcompass::graph::inference::Simulator;
use llmcompass::perf::mapper::{Mapper, SearchBudget};
use llmcompass::util::json::Json;
use std::path::{Path, PathBuf};

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

#[test]
fn shipped_suite_evaluates_with_stable_schema() {
    let suite = eval::load_suite(&scenarios_dir()).unwrap();
    assert!(suite.len() >= 3, "the sample suite ships at least 3 scenarios");
    let ev = Evaluator::new();
    let reports = ev.evaluate_suite(&suite, 2);
    for (sc, rep) in suite.iter().zip(&reports) {
        let rep = rep.as_ref().unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        let j = rep.to_json();
        assert_eq!(
            j.get("schema_version").and_then(Json::as_u64),
            Some(SCHEMA_VERSION),
            "{}",
            sc.name
        );
        assert!(j.get("scenario").is_some());
        assert!(j.get("hardware").and_then(|h| h.get("device")).is_some());
        let results = j.get("results").unwrap();
        for o in &sc.outputs {
            assert!(results.get(o.name()).is_some(), "{}: missing `{}`", sc.name, o.name());
        }
        // Every report is valid JSON text that reparses to itself.
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j, "{}", sc.name);
    }
    // The traffic comparison scenarios actually exercised the serving path.
    let a100 = suite.iter().position(|sc| sc.name == "a100-traffic").unwrap();
    let serving = reports[a100].as_ref().unwrap().to_json();
    let summary = serving.get("results").unwrap().get("serving").unwrap().get("summary").unwrap();
    assert_eq!(summary.get("requests").and_then(Json::as_u64), Some(48));
    assert!(summary.get("throughput_tok_s").and_then(Json::as_f64).unwrap() > 0.0);
}

#[test]
fn shipped_suite_round_trips_losslessly() {
    for sc in eval::load_suite(&scenarios_dir()).unwrap() {
        let again = Scenario::parse(&sc.to_json().to_string_pretty())
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        assert_eq!(sc, again, "{} changed across serialize → parse", sc.name);
    }
}

#[test]
fn warm_persistent_cache_makes_repeated_suite_search_free() {
    // The persistent-cache acceptance criterion: after one cold run of
    // the shipped suite persists its mapping cache, a fresh process-like
    // evaluator re-running `eval --suite scenarios/` must perform ZERO
    // mapper parameter searches — every (device, shape) is served from
    // disk, including everything the serving simulations touch.
    let cache = std::env::temp_dir()
        .join(format!("llmcompass-suite-mapper-cache-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&cache);
    let suite = eval::load_suite(&scenarios_dir()).unwrap();

    let cold = Evaluator::with_sim(Simulator::with_mapper(Mapper::with_cache(
        SearchBudget::default(),
        &cache,
    )));
    assert_eq!(cold.sim.mapper.loaded_from_disk(), 0);
    let cold_reports: Vec<_> = suite
        .iter()
        .map(|sc| cold.evaluate(sc).unwrap_or_else(|e| panic!("{}: {e}", sc.name)))
        .collect();
    let cold_searches = cold.sim.mapper.searches();
    assert!(cold_searches > 0, "cold run must actually search");
    cold.sim.mapper.persist().unwrap();

    let warm = Evaluator::with_sim(Simulator::with_mapper(Mapper::with_cache(
        SearchBudget::default(),
        &cache,
    )));
    assert_eq!(warm.sim.mapper.loaded_from_disk() as usize, cold.sim.mapper.cache_len());
    let warm_reports = warm.evaluate_suite(&suite, 2);
    for (a, b) in cold_reports.iter().zip(&warm_reports) {
        let b = b.as_ref().unwrap();
        assert_eq!(a.to_json(), b.to_json(), "cache-served report drifted");
    }
    assert_eq!(
        warm.sim.mapper.searches(),
        0,
        "a warm persistent cache must make the repeated suite search-free"
    );
    let _ = std::fs::remove_file(&cache);
}

#[test]
fn shared_evaluator_beats_independent_runs_on_searches() {
    // The cross-scenario cache acceptance criterion, on the real suite:
    // one evaluator over all scenarios must perform strictly fewer mapper
    // parameter searches than one fresh evaluator per scenario.
    let suite = eval::load_suite(&scenarios_dir()).unwrap();
    let shared = Evaluator::new();
    for sc in &suite {
        shared.evaluate(sc).unwrap_or_else(|e| panic!("{}: {e}", sc.name));
    }
    let shared_searches = shared.sim.mapper.searches();

    let mut independent_searches = 0;
    for sc in &suite {
        let ev = Evaluator::new();
        ev.evaluate(sc).unwrap();
        independent_searches += ev.sim.mapper.searches();
    }
    assert!(
        shared_searches < independent_searches,
        "shared evaluator did {shared_searches} searches, independent runs {independent_searches}"
    );
}
