//! Property-based tests over the performance / area / cost models, driven
//! by the in-crate `util::quick` framework: random devices and shapes must
//! satisfy the physical invariants the paper's methodology rests on.

use llmcompass::arch::systolic::{
    cycles_analytical, cycles_reference, Array, Dataflow, SystolicLut, Tile,
};
use llmcompass::hardware::{presets, DType, DeviceSpec};
use llmcompass::perf::mapper::{search, SearchBudget};
use llmcompass::perf::matmul::{fits, lower_bound, simulate, Mapping, Scheme, Shape};
use llmcompass::util::quick::{forall, Gen};

/// Draw a random-but-plausible device from the GA100 template.
fn gen_device(g: &mut Gen) -> DeviceSpec {
    let mut d = presets::ga100();
    d.core_count = g.pow2(3, 7); // 8..128
    d.core.lane_count = g.pow2(0, 2);
    d.core.lane.vector_width = g.pow2(3, 7);
    let s = g.pow2(3, 7); // 8..128
    d.core.lane.systolic_rows = s;
    d.core.lane.systolic_cols = s;
    d.core.local_buffer_bytes = g.pow2(15, 21); // 32KB..2MB
    d.global_buffer_bytes = g.pow2(22, 26); // 4MB..64MB
    d.memory.bandwidth_bytes_per_s = g.u64(200, 3200) as f64 * 1e9;
    d.name = format!("rand-{}", g.u64(0, u64::MAX / 2));
    d
}

fn gen_shape(g: &mut Gen) -> Shape {
    Shape {
        b: 1,
        m: g.pow2(0, 12),
        k: g.pow2(4, 13),
        n: g.pow2(4, 13),
        dtype: DType::FP16,
        batched_b: false,
    }
}

#[test]
fn prop_simulated_latency_respects_rooflines() {
    let lut = SystolicLut::new();
    forall("latency >= max(compute, io) roofline", 60, |g| {
        let dev = gen_device(g);
        let shape = gen_shape(g);
        let best = search(&dev, &shape, SearchBudget::default(), &lut);
        let compute = shape.flops() / dev.peak_matrix_flops();
        let io = (shape.m * shape.k + shape.k * shape.n + shape.m * shape.n) as f64
            * shape.dtype.bytes() as f64
            / dev.memory.bandwidth_bytes_per_s;
        let bound = compute.max(io) * 0.999;
        (
            (shape, dev.name.clone(), best.outcome.seconds, bound),
            best.outcome.seconds >= bound,
        )
    });
}

/// Draw a random mapping over pow2 tiles (not necessarily feasible).
fn gen_mapping(g: &mut Gen) -> Mapping {
    let gt = (g.pow2(3, 13), g.pow2(3, 13), g.pow2(3, 13));
    Mapping {
        gt,
        lt: (
            g.pow2(3, 8).min(gt.0),
            g.pow2(3, 8).min(gt.1),
            g.pow2(3, 8).min(gt.2),
        ),
        scheme: *g.pick(&[Scheme::OutputPartitioned, Scheme::KSplit]),
        db_global: g.bool(),
        db_local: g.bool(),
    }
}

#[test]
fn prop_lower_bound_never_exceeds_simulated_time() {
    // The soundness contract of the mapper engine's pruning oracle: for
    // every feasible (device, shape, mapping), the analytical floor must
    // not exceed the full tile-by-tile simulation — otherwise pruning
    // could discard the true winner and break the bit-identical-winner
    // guarantee.
    let lut = SystolicLut::new();
    let feasible = std::cell::Cell::new(0u32);
    forall("lower_bound <= simulate", 400, |g| {
        let dev = gen_device(g);
        let mut shape = gen_shape(g);
        if g.bool() {
            shape.b = g.u64(1, 96);
            shape.batched_b = g.bool();
        }
        let map = gen_mapping(g);
        if !fits(&dev, &shape, &map) {
            return ((shape, map, 0.0, 0.0), true); // vacuous: mapper never simulates it
        }
        feasible.set(feasible.get() + 1);
        let sim = simulate(&dev, &shape, &map, &lut).unwrap();
        let lb = lower_bound(&dev, &shape, &map);
        ((shape, map, lb, sim.seconds), lb <= sim.seconds)
    });
    assert!(
        feasible.get() > 50,
        "only {} feasible draws — generator drifted",
        feasible.get()
    );
}

#[test]
fn prop_pruned_search_matches_exhaustive() {
    // Winner identity on random devices/shapes, not just the preset grid.
    let lut = SystolicLut::new();
    forall("pruned winner == exhaustive winner", 15, |g| {
        let dev = gen_device(g);
        let shape = gen_shape(g);
        let ex = search(&dev, &shape, SearchBudget::exhaustive(), &lut);
        let pr = search(&dev, &shape, SearchBudget::default(), &lut);
        (
            (shape, dev.name.clone(), ex.mapping, pr.mapping),
            ex.mapping == pr.mapping
                && ex.outcome.seconds.to_bits() == pr.outcome.seconds.to_bits()
                && pr.rounds <= ex.rounds,
        )
    });
}

#[test]
fn prop_more_bandwidth_never_slower() {
    let lut = SystolicLut::new();
    forall("bandwidth monotonicity", 30, |g| {
        let mut dev = gen_device(g);
        let shape = gen_shape(g);
        let t1 = search(&dev, &shape, SearchBudget::default(), &lut).outcome.seconds;
        dev.memory.bandwidth_bytes_per_s *= 2.0;
        let t2 = search(&dev, &shape, SearchBudget::default(), &lut).outcome.seconds;
        ((shape, t1, t2), t2 <= t1 * 1.0001)
    });
}

#[test]
fn prop_bigger_buffers_never_slower() {
    let lut = SystolicLut::new();
    forall("buffer monotonicity", 30, |g| {
        let mut dev = gen_device(g);
        let shape = gen_shape(g);
        let t1 = search(&dev, &shape, SearchBudget::default(), &lut).outcome.seconds;
        dev.core.local_buffer_bytes *= 2;
        dev.global_buffer_bytes *= 2;
        let t2 = search(&dev, &shape, SearchBudget::default(), &lut).outcome.seconds;
        // Larger buffers strictly widen the feasible mapping set.
        ((shape, t1, t2), t2 <= t1 * 1.0001)
    });
}

#[test]
fn prop_systolic_analytical_bounded_by_reference() {
    forall("analytical <= no-overlap reference", 200, |g| {
        let tile = Tile { m: g.u64(1, 512), k: g.u64(1, 512), n: g.u64(1, 512) };
        let array = Array {
            rows: g.pow2(2, 7),
            cols: g.pow2(2, 7),
            dataflow: if g.bool() {
                Dataflow::WeightStationary
            } else {
                Dataflow::OutputStationary
            },
        };
        let a = cycles_analytical(tile, array);
        let r = cycles_reference(tile, array);
        // And both at least cover the streaming lower bound.
        let macs = tile.m * tile.k * tile.n;
        let min_cycles = macs / (array.rows * array.cols);
        ((tile, array, a, r), a <= r && a >= min_cycles.min(a))
    });
}

#[test]
fn prop_allreduce_at_least_bandwidth_bound() {
    forall("ring all-reduce >= 2(g-1)/g bound", 200, |g| {
        let ic = llmcompass::hardware::InterconnectSpec::nvlink_like(
            g.u64(50, 900) as f64 * 1e9,
        );
        let bytes = g.u64(1, 1 << 30);
        let devices = g.u64(2, 16);
        let r = llmcompass::perf::comm::all_reduce(&ic, bytes, devices);
        ((bytes, devices), r.latency_s >= r.memory_bound_s * 0.999)
    });
}

#[test]
fn prop_device_json_roundtrip() {
    forall("device JSON round-trip", 100, |g| {
        let dev = gen_device(g);
        let json = dev.to_json().to_string_pretty();
        let parsed = llmcompass::util::json::Json::parse(&json).unwrap();
        let back = DeviceSpec::from_json(&parsed).unwrap();
        (dev.name.clone(), back == dev)
    });
}

#[test]
fn prop_area_monotone_in_resources() {
    forall("area grows with cores and buffers", 100, |g| {
        let dev = gen_device(g);
        let a1 = llmcompass::area::die_mm2(&dev);
        let mut bigger = dev.clone();
        bigger.core_count += g.u64(1, 32);
        bigger.global_buffer_bytes += g.u64(1, 32) * 1024 * 1024;
        let a2 = llmcompass::area::die_mm2(&bigger);
        ((dev.name.clone(), a1, a2), a2 > a1)
    });
}

#[test]
fn prop_cost_monotone_in_area() {
    let p = llmcompass::cost::CostParams::default();
    forall("die cost grows with area", 200, |g| {
        let a1 = g.f64(10.0, 800.0);
        let delta = g.f64(1.0, 100.0);
        let c1 = llmcompass::cost::die_cost_usd(&p, a1);
        let c2 = llmcompass::cost::die_cost_usd(&p, a1 + delta);
        ((a1, delta), c2 > c1)
    });
}

#[test]
fn prop_decode_latency_monotone_in_kv() {
    // Longer KV ⇒ strictly more traffic ⇒ never faster decode.
    let sim = llmcompass::graph::inference::Simulator::new();
    let model = llmcompass::graph::ModelConfig::gpt3_175b();
    let sys = presets::system("a100x4").unwrap();
    forall("decode monotone in kv length", 20, |g| {
        let kv = g.u64(64, 4096);
        let t1 = sim.decode(&sys, &model, 8, kv, 1);
        let t2 = sim.decode(&sys, &model, 8, kv + g.u64(1, 2048), 1);
        ((kv, t1, t2), t2 >= t1 * 0.9999)
    });
}

#[test]
fn prop_e2e_latency_additive() {
    // e2e(in, out) >= prefill(in) and grows with out.
    let sim = llmcompass::graph::inference::Simulator::new();
    let model = llmcompass::graph::ModelConfig::gpt3_175b();
    let sys = presets::system("a100x4").unwrap();
    forall("e2e latency decomposition", 10, |g| {
        let s_in = g.pow2(6, 11);
        let s_out = g.pow2(4, 9);
        let pre = sim.prefill(&sys, &model, 8, s_in, 4);
        let e1 = sim.e2e_latency(&sys, &model, 8, s_in, s_out, 4);
        let e2 = sim.e2e_latency(&sys, &model, 8, s_in, s_out * 2, 4);
        ((s_in, s_out), e1 > pre && e2 > e1)
    });
}
