//! Integration tests for the telemetry subsystem (`util::telemetry`):
//! the Chrome-trace export attached to an `Evaluator` must tell the same
//! story the serving report tells in aggregate.
//!
//! Three contracts:
//! * every "preempt" instant in the trace is one scheduler preemption —
//!   the event count equals `RunStats.preemptions` exactly;
//! * the simulated-time trace is a pure function of the scenario: two
//!   seeded runs serialize byte-identically (host wall-clock events live
//!   in a separate trace process precisely so they can be excluded);
//! * the shipped disaggregated sample produces the full observability
//!   surface — request-lifecycle spans, per-pool KV/batch counter
//!   tracks, and handoff instrumentation.

use llmcompass::eval::{EvalResult, Evaluator, Scenario};
use llmcompass::util::json::Json;
use llmcompass::util::telemetry::Recorder;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

fn load(name: &str) -> Scenario {
    Scenario::load(&scenarios_dir().join(name)).expect("shipped scenario loads")
}

/// Evaluate `sc` on a fresh serial evaluator with tracing on; return the
/// recorder and the evaluated report.
fn traced_eval(sc: &Scenario) -> (Arc<Recorder>, llmcompass::eval::EvalReport) {
    let rec = Arc::new(Recorder::enabled());
    let ev = Evaluator::new().with_recorder(rec.clone());
    let rep = ev.evaluate(sc).expect("scenario evaluates");
    (rec, rep)
}

fn events(trace: &Json) -> &[Json] {
    trace.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array")
}

fn count_named(trace: &Json, ph: &str, name: &str) -> usize {
    events(trace)
        .iter()
        .filter(|e| {
            e.get("ph").and_then(Json::as_str) == Some(ph)
                && e.get("name").and_then(Json::as_str) == Some(name)
        })
        .count()
}

fn serving_stats(rep: &llmcompass::eval::EvalReport) -> &llmcompass::serve::RunStats {
    rep.results
        .iter()
        .find_map(|r| match r {
            EvalResult::Serving(sr) => Some(&sr.stats),
            _ => None,
        })
        .expect("serving result present")
}

#[test]
fn preempt_instants_match_the_preemption_counter_exactly() {
    let sc = load("a100_evict.json");
    let (rec, rep) = traced_eval(&sc);
    let stats = serving_stats(&rep);
    assert!(
        stats.preemptions > 0,
        "the evict sample must exercise preemption or this test is vacuous"
    );
    let trace = rec.to_json();
    assert_eq!(
        count_named(&trace, "i", "preempt") as u64,
        stats.preemptions,
        "one `preempt` instant per scheduler preemption, no more, no less"
    );
}

#[test]
fn seeded_runs_emit_byte_identical_simulated_time_traces() {
    let sc = load("a100_evict.json");
    let (rec_a, _) = traced_eval(&sc);
    let (rec_b, _) = traced_eval(&sc);
    let a = rec_a.sim_trace_json().to_string_compact();
    let b = rec_b.sim_trace_json().to_string_compact();
    assert!(!a.is_empty() && a.contains("traceEvents"));
    assert_eq!(a, b, "simulated-time trace must be a pure function of the scenario");
}

#[test]
fn disaggregated_trace_carries_lifecycle_pool_and_handoff_tracks() {
    let sc = load("a100x4_disagg.json");
    let (rec, rep) = traced_eval(&sc);
    let stats = serving_stats(&rep);
    let trace = rec.to_json();

    // Request lifecycle: every request gets queued → prefill → handoff →
    // decode spans plus first-token/done instants.
    for name in ["queued", "prefill", "handoff", "decode"] {
        assert!(count_named(&trace, "X", name) > 0, "missing lifecycle span `{name}`");
    }
    assert!(count_named(&trace, "i", "first_token") > 0);
    assert!(count_named(&trace, "i", "done") > 0);

    // Per-pool counter tracks sample KV occupancy and batch size.
    for name in [
        "kv_tokens (prefill pool)",
        "batch (prefill pool)",
        "kv_tokens (decode pool)",
        "batch (decode pool)",
    ] {
        assert!(count_named(&trace, "C", name) > 0, "missing counter track `{name}`");
    }

    // Handoff stalls appear as spans iff the report says the prefill
    // pool stalled.
    let stalls = count_named(&trace, "X", "handoff_stall");
    if stats.handoff_stall_s > 0.0 {
        assert!(stalls > 0, "report shows stall time but the trace has no stall spans");
    } else {
        assert_eq!(stalls, 0, "trace shows stalls the report never accounted for");
    }

    // Every event in the export has a well-formed phase, and complete
    // spans never run backwards.
    for e in events(&trace) {
        let ph = e.get("ph").and_then(Json::as_str).expect("event has ph");
        assert!(["X", "C", "i", "M"].contains(&ph), "unexpected phase {ph:?}");
        if ph == "X" {
            assert!(e.get("dur").and_then(Json::as_f64).expect("span has dur") >= 0.0);
        }
    }
}

/// The faulty disaggregated sample must surface its injected faults in
/// the trace: scheduled windows as spans on the "faults" track, plus a
/// crash-application instant and a downtime span when the crash lands.
#[test]
fn fault_windows_surface_on_their_own_trace_track() {
    let sc = load("a100x4_disagg_faulty.json");
    let (rec, rep) = traced_eval(&sc);
    let stats = serving_stats(&rep);
    assert!(stats.requests_lost > 0, "the faulty sample must actually lose requests");
    let trace = rec.to_json();
    assert!(count_named(&trace, "X", "link_degrade") > 0, "scheduled link_degrade span missing");
    assert!(count_named(&trace, "X", "crash") > 0, "scheduled crash span missing");
    assert!(count_named(&trace, "i", "crash") > 0, "crash-application instant missing");
    assert!(count_named(&trace, "X", "downtime") > 0, "downtime span missing");
}

#[test]
fn disabled_recorder_leaves_reports_and_traces_empty_of_events() {
    // The default evaluator carries the no-op recorder: same report,
    // zero telemetry events, nothing to write.
    let sc = load("a100_evict.json");
    let ev = Evaluator::new();
    let rep = ev.evaluate(&sc).expect("scenario evaluates");
    assert!(serving_stats(&rep).preemptions > 0);
    assert!(!ev.recorder().is_enabled());
    assert_eq!(ev.recorder().event_count(), 0);
}
