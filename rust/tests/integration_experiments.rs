//! Every experiment regenerator runs end to end (quick mode) and its
//! report carries the paper's key signals.

use llmcompass::experiments::{registry, run, Ctx};

#[test]
fn all_simulation_experiments_run_quick() {
    let ctx = Ctx::new(true);
    for (id, _, _) in registry() {
        if id == "fig5" {
            continue; // needs artifacts; covered below when present
        }
        let out = run(id, &ctx).unwrap_or_else(|e| panic!("{id} failed: {e:#}"));
        assert!(!out.is_empty(), "{id} produced no report");
    }
}

#[test]
fn fig6_reports_area_errors_within_band() {
    let ctx = Ctx::new(true);
    let out = run("fig6", &ctx).unwrap();
    assert!(out.contains("GA100"));
    assert!(out.contains("Aldebaran"));
    assert!(out.contains("error %"));
}

#[test]
fn fig7_shape_matches_paper() {
    let ctx = Ctx::new(true);
    let out = run("fig7", &ctx).unwrap();
    // Designs table + both implications printed with ratios.
    assert!(out.contains("implication ①"));
    assert!(out.contains("implication ②"));
    assert!(out.contains("128x128"));
}

#[test]
fn fig10_average_near_paper() {
    let ctx = Ctx::new(true);
    let out = run("fig10", &ctx).unwrap();
    // Extract "average normalized performance: X"
    let avg: f64 = out
        .lines()
        .find(|l| l.starts_with("average normalized performance"))
        .and_then(|l| l.split(':').nth(1))
        .and_then(|v| v.trim().split_whitespace().next())
        .and_then(|v| v.parse().ok())
        .expect("average line");
    assert!((0.85..1.0).contains(&avg), "fig10 average {avg} (paper 0.953)");
}

#[test]
fn fig12_throughput_design_wins() {
    let ctx = Ctx::new(true);
    let out = run("fig12", &ctx).unwrap();
    let avg: f64 = out
        .lines()
        .find(|l| l.starts_with("average normalized throughput"))
        .and_then(|l| l.split(':').nth(1))
        .and_then(|v| {
            v.trim()
                .trim_end_matches(|c: char| !c.is_ascii_digit() && c != '.')
                .split('x')
                .next()
        })
        .and_then(|v| v.parse().ok())
        .expect("average line");
    assert!(avg > 1.0, "throughput design should beat GA100, got {avg}");
    assert!(avg < 3.0, "throughput ratio {avg} implausibly high");
}

#[test]
fn tab4_reproduces_cost_rows() {
    let ctx = Ctx::new(true);
    let out = run("tab4", &ctx).unwrap();
    assert!(out.contains("normalized perf/cost"));
    assert!(out.contains("PCIe5.0/CXL"));
    assert!(out.contains("$"));
}

#[test]
fn fig5_runs_when_artifacts_present() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipped: fig5 needs artifacts (run `make artifacts`)");
        return;
    }
    let ctx = Ctx::new(true);
    let out = run("fig5", &ctx).unwrap();
    assert!(out.contains("overall mean |error|"));
    assert!(out.contains("trend"));
    // Reports must have been written.
    assert!(std::path::Path::new("reports/fig5.csv").exists());
}

#[test]
fn reports_directory_gets_csvs() {
    let ctx = Ctx::new(true);
    run("fig7", &ctx).unwrap();
    run("fig8", &ctx).unwrap();
    for f in ["reports/fig7.csv", "reports/fig7_breakdown.csv", "reports/fig8.csv"] {
        let content = std::fs::read_to_string(f).unwrap_or_else(|_| panic!("{f} missing"));
        assert!(content.lines().count() > 2, "{f} too short");
    }
}
