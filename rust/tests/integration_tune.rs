//! Integration tests for the design-space autotuner (`tune`):
//!
//! * the ISSUE's acceptance bar — on the Section-VII-style scenario the
//!   searched frontier's best perf/$ point strictly beats the stock
//!   A100 — locked by a golden `TuneReport` under `tests/golden/tune/`
//!   (same bootstrap/update workflow as the eval golden harness; the
//!   subdirectory keeps these goldens out of the eval harness's
//!   "gate armed" scan, which is intentionally non-recursive);
//! * the branch-and-bound identity: pruning must return the
//!   bit-identical frontier of the exhaustive sweep (the floors are
//!   provable lower bounds, so a pruned design is strictly dominated);
//! * report invariants: the frontier carries no dominated point, the
//!   best point sits on it, and the search accounting adds up.
//!
//! Search accounting (`pruned`/`evaluated`) depends on which designs
//! finish first across threads, so golden comparison ignores those two
//! counters; every modeled value stays locked.

use llmcompass::eval::{Evaluator, Scenario};
use llmcompass::tune::{self, DesignSpace, Objective, TuneOptions, TuneReport};
use llmcompass::util::json::{diff_with_tolerance_ignoring, Json};
use std::path::{Path, PathBuf};

const REL_TOL: f64 = 1e-9;
const ABS_TOL: f64 = 1e-12;

/// Thread-timing-dependent accounting, excluded from golden comparison
/// (a design may be pruned or evaluated depending on completion order;
/// the frontier is provably identical either way).
const IGNORED_PATHS: &[&str] = &["search.pruned", "search.evaluated"];

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

fn golden_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/tune/tune_section7.json")
}

fn update_mode() -> bool {
    std::env::var("GOLDEN_UPDATE").map(|v| v == "1").unwrap_or(false)
}

/// No frontier point may dominate another, and `best` must sit on the
/// frontier (both objectives are monotone in a frontier axis for their
/// natural workloads).
fn assert_frontier_sound(report: &TuneReport) {
    for (i, a) in report.frontier.iter().enumerate() {
        for (j, b) in report.frontier.iter().enumerate() {
            if i != j {
                assert!(
                    !tune::dominates(a, b),
                    "frontier point `{}` dominates `{}`",
                    a.name,
                    b.name
                );
            }
        }
    }
    let best = report.best.as_ref().expect("search produced a best point");
    assert!(
        report.frontier.iter().any(|p| p.name == best.name),
        "best point `{}` is not on the frontier",
        best.name
    );
}

#[test]
fn section7_search_beats_stock_a100() {
    let sc = Scenario::load(&scenarios_dir().join("tune_section7_request.json")).unwrap();
    let spec = sc.tune.clone().expect("scenario carries a tune section");
    let space = DesignSpace::resolve(&spec.space).unwrap();
    assert_eq!(spec.objective, Some(Objective::PerfPerDollar));

    let ev = Evaluator::new();
    let report =
        tune::tune(&ev, &sc, &space, Objective::PerfPerDollar, &TuneOptions::default()).unwrap();

    assert_eq!(report.designs_total, 6, "section7 = 3 core counts x 2 memories");
    assert_eq!(
        report.evaluated + report.pruned + report.infeasible + report.cache_hits,
        report.designs_total,
        "search accounting must add up"
    );
    assert!(!report.frontier.is_empty(), "searched frontier is empty");
    assert_frontier_sound(&report);

    // The acceptance bar: the best perf/$ design strictly beats the
    // scenario's stock A100. Decode dominates this workload and is
    // memory-bound, so reduced-compute designs lose little latency while
    // shedding die cost — the gain must be real, not a tie.
    let best = report.best.as_ref().unwrap();
    let baseline = report.baseline.as_ref().expect("stock baseline evaluated");
    let gain = report.gain_vs_baseline().unwrap();
    assert!(
        gain > 1.0,
        "best design `{}` does not beat stock ({}x, best {:.3e} vs baseline {:.3e})",
        best.name,
        gain,
        Objective::PerfPerDollar.value(best),
        Objective::PerfPerDollar.value(baseline)
    );
    // perf/$ on a request workload is monotone in $/1M-tokens, so the
    // winner must also be strictly cheaper per token.
    assert!(
        best.usd_per_mtok < baseline.usd_per_mtok,
        "best {} $/1Mtok vs baseline {}",
        best.usd_per_mtok,
        baseline.usd_per_mtok
    );

    // Golden lock (bootstrap on first toolchain-equipped run).
    let actual = report.to_json();
    assert_eq!(actual.get("schema_version").and_then(Json::as_u64), Some(1));
    let path = golden_path();
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    if update_mode() || !path.exists() {
        std::fs::write(&path, actual.to_string_pretty()).unwrap();
        println!(
            "golden: materialized {} — commit it to lock the tune report",
            path.display()
        );
        return;
    }
    let expected = Json::parse(&std::fs::read_to_string(&path).unwrap())
        .unwrap_or_else(|e| panic!("golden {} is not valid JSON: {e}", path.display()));
    let diffs = diff_with_tolerance_ignoring(&expected, &actual, REL_TOL, ABS_TOL, IGNORED_PATHS);
    if !diffs.is_empty() {
        let mut msg = format!(
            "tune report drifted from {} ({} field(s)):\n",
            path.display(),
            diffs.len()
        );
        for d in &diffs {
            msg.push_str(&format!("    {d}\n"));
        }
        panic!(
            "{msg}\nIntentional change? regenerate with \
             `GOLDEN_UPDATE=1 cargo test --test integration_tune` and commit the diff."
        );
    }
}

#[test]
fn branch_and_bound_frontier_is_bit_identical_to_exhaustive() {
    // A cheap request scenario over the CI-sized space: the pruned and
    // exhaustive searches must agree on the frontier bit for bit (same
    // points, same order, same float bits) — the documented guarantee of
    // the floor-domination pruning rule. No cache file: both runs
    // evaluate from scratch.
    let sc = Scenario::new(
        "tune-identity",
        "a100",
        llmcompass::eval::Workload::Request {
            model: "gpt-small".to_string(),
            batch: 2,
            prefill: 16,
            decode: 4,
            layers: Some(1),
        },
    );
    let space = DesignSpace::preset("smoke").unwrap();
    let ev = Evaluator::new();

    let pruned = tune::tune(
        &ev,
        &sc,
        &space,
        Objective::PerfPerDollar,
        &TuneOptions::default(),
    )
    .unwrap();
    let exhaustive = tune::tune(
        &ev,
        &sc,
        &space,
        Objective::PerfPerDollar,
        &TuneOptions { exhaustive: true, ..TuneOptions::default() },
    )
    .unwrap();

    assert_eq!(exhaustive.pruned, 0, "exhaustive mode must not prune");
    assert_eq!(
        exhaustive.evaluated + exhaustive.infeasible,
        exhaustive.designs_total,
        "exhaustive mode must evaluate every feasible design"
    );

    let frontier_json = |r: &TuneReport| {
        Json::Arr(r.frontier.iter().map(|p| p.to_json()).collect()).to_string_compact()
    };
    assert_eq!(
        frontier_json(&pruned),
        frontier_json(&exhaustive),
        "pruned frontier drifted from the exhaustive sweep"
    );
    assert_eq!(
        pruned.best.as_ref().map(|b| b.name.clone()),
        exhaustive.best.as_ref().map(|b| b.name.clone()),
        "best-point winner drifted under pruning"
    );
    assert_frontier_sound(&pruned);
}

#[test]
fn dram_traffic_scenario_tunes_on_goodput() {
    // The traffic-flavored Section-VII scenario: resolves its space from
    // the tune section, defaults to goodput/$, and produces a sound
    // frontier. (No golden: serving metrics are already locked by the
    // eval golden suite; this guards the tune plumbing end to end.)
    let sc = Scenario::load(&scenarios_dir().join("tune_section7_dram.json")).unwrap();
    let spec = sc.tune.clone().expect("scenario carries a tune section");
    assert_eq!(spec.objective, Some(Objective::GoodputPerDollar));
    assert_eq!(
        Objective::default_for(&sc.workload),
        Objective::GoodputPerDollar,
        "traffic workloads default to goodput/$"
    );
    let space = DesignSpace::resolve(&spec.space).unwrap();
    let ev = Evaluator::new();
    let report =
        tune::tune(&ev, &sc, &space, Objective::GoodputPerDollar, &TuneOptions::default())
            .unwrap();
    assert!(!report.frontier.is_empty());
    assert!(report.baseline.is_some());
    assert_frontier_sound(&report);
}
