//! Integration: the cluster serving simulator end to end — workload →
//! scheduler v2 (monolithic / chunked / disaggregated, conservative /
//! evict) → metrics → SLO cost sweep — on real hardware presets,
//! including KV accounting for GPT-3-class models, the chunked-vs-
//! monolithic TTFT acceptance criterion on the shipped bursty sample
//! scenario, and byte-identical deterministic replay of `ServeReport`s.

use llmcompass::eval::{self, Workload};
use llmcompass::graph::inference::Simulator;
use llmcompass::graph::ModelConfig;
use llmcompass::hardware::{config, presets};
use llmcompass::serve::{
    self, kv_capacity_tokens, Arrival, Policy, Preemption, SchedulerConfig, ServeMode, Slo,
    WorkloadSpec,
};
use std::path::{Path, PathBuf};

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

/// Run one shipped traffic scenario through the exact configuration the
/// evaluator would use — including its fleet shape — returning the full
/// report.
fn serve_scenario(name: &str) -> serve::ServeReport {
    let suite = eval::load_suite(&scenarios_dir()).unwrap();
    let sc = suite
        .iter()
        .find(|sc| sc.name == name)
        .unwrap_or_else(|| panic!("scenario `{name}` missing from scenarios/"));
    let Workload::Traffic(t) = &sc.workload else { panic!("`{name}` is not traffic") };
    let sys = config::resolve(&sc.hardware).unwrap();
    let model = eval::model_by_name(&t.model).unwrap();
    let cfg = eval::scheduler_config_for(&sys, &model, t).unwrap();
    let requests = eval::traffic_requests(t).unwrap();
    let sim = Simulator::new();
    let fleet = serve::FleetConfig { replicas: t.replicas, balancer: t.balancer };
    let (report, _) = serve::serve_fleet(&sim, &sys, &model, &cfg, &fleet, &requests, &t.slo);
    report
}

#[test]
fn thousand_requests_complete_with_consistent_accounting() {
    let sim = Simulator::new();
    let sys = presets::system("a100").unwrap();
    let model = ModelConfig::gpt_small();
    let cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
    let reqs = serve::workload::generate(&WorkloadSpec::poisson(30.0, 1000, 42));
    let (report, per_req) = serve::serve_once(&sim, &sys, &model, &cfg, &reqs, &Slo::interactive());
    let (summary, stats) = (&report.summary, &report.stats);

    assert_eq!(summary.requests, 1000);
    let total_out: u64 = reqs.iter().map(|r| r.output_tokens).sum();
    assert_eq!(summary.output_tokens, total_out);
    for (m, r) in per_req.iter().zip(&reqs) {
        assert_eq!(m.id, r.id);
        assert!(m.ttft_s() > 0.0, "request {} TTFT {}", m.id, m.ttft_s());
        assert!(m.e2e_s() >= m.ttft_s());
    }
    // Percentile ordering and conservation.
    assert!(summary.ttft_p50_s <= summary.ttft_p99_s);
    assert!(summary.tpot_p50_s <= summary.tpot_p99_s);
    assert!(summary.goodput_tok_s <= summary.throughput_tok_s + 1e-12);
    assert!((0.0..=1.0).contains(&summary.slo_attainment));
    // The busy/idle split covers the makespan (admission itself is free;
    // monolithic mode has no mixed iterations).
    let accounted = stats.prefill_busy_s + stats.decode_busy_s + stats.mixed_busy_s + stats.idle_s;
    assert!(
        (accounted - stats.makespan_s).abs() < 1e-6 * stats.makespan_s.max(1.0),
        "accounted {accounted:.3} vs makespan {:.3}",
        stats.makespan_s
    );
    assert_eq!(stats.mixed_iterations, 0);
    assert_eq!(stats.preemptions, 0);
    assert!(stats.peak_kv_tokens <= cfg.kv_capacity_tokens);
    assert!(stats.peak_batch <= cfg.max_batch);
}

#[test]
fn gpt3_on_a100x8_respects_kv_budget() {
    // GPT-3 on one 8×A100 node: ~290 GB free after weights → ~61k KV
    // tokens. The scheduler must stay under that while still serving.
    let sim = Simulator::new();
    let sys = presets::system("a100x8").unwrap();
    let model = ModelConfig::gpt3_175b();
    let budget = kv_capacity_tokens(&sys, &model);
    assert!((50_000..75_000).contains(&budget), "KV budget {budget}");

    let mut cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
    cfg.max_batch = 8;
    cfg.max_prefill_batch = 4;
    let spec = WorkloadSpec {
        arrival: Arrival::Poisson { rate_per_s: 4.0 },
        prompt: serve::LengthDist::Fixed(512),
        output: serve::LengthDist::Fixed(64),
        requests: 50,
        seed: 7,
        diurnal: None,
        flash_crowd: None,
    };
    let reqs = serve::workload::generate(&spec);
    let (report, _) = serve::serve_once(&sim, &sys, &model, &cfg, &reqs, &Slo::relaxed());
    let (summary, stats) = (&report.summary, &report.stats);
    assert_eq!(summary.requests, 50);
    assert!(stats.peak_kv_tokens <= budget);
    assert!(stats.peak_kv_tokens >= 8 * (512 + 64), "batch never filled");
    assert!(summary.throughput_tok_s > 0.0);
    // Decode of a GPT-3 batch is milliseconds-per-token territory, not
    // microseconds and not seconds (paper Fig. 11 scale).
    assert!(
        (1e-3..1.0).contains(&summary.tpot_p50_s),
        "TPOT p50 {:.4}s",
        summary.tpot_p50_s
    );
}

#[test]
fn burst_arrivals_queue_worse_than_spaced_arrivals() {
    // Deterministic queueing check: the same 100 requests delivered as one
    // instantaneous burst vs generously spaced. The burst forces later
    // requests to wait behind earlier prefill batches, so mean TTFT must
    // be strictly worse; spacing slower than service keeps queues empty.
    let sim = Simulator::new();
    let sys = presets::system("a100").unwrap();
    let model = ModelConfig::gpt_small();
    let cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
    let mk = |spacing_s: f64| -> Vec<serve::Request> {
        (0..100)
            .map(|i| serve::Request {
                id: i,
                arrival_s: i as f64 * spacing_s,
                prompt_tokens: 512,
                output_tokens: 16,
            })
            .collect()
    };
    let burst = mk(0.0);
    let spaced = mk(0.5);
    let (b, _) = serve::serve_once(&sim, &sys, &model, &cfg, &burst, &Slo::interactive());
    let (s, _) = serve::serve_once(&sim, &sys, &model, &cfg, &spaced, &Slo::interactive());
    assert!(
        b.summary.ttft_mean_s > s.summary.ttft_mean_s,
        "burst mean TTFT {:.4}s should exceed spaced {:.4}s",
        b.summary.ttft_mean_s,
        s.summary.ttft_mean_s
    );
    // The bursty arrival *process* also drives the scheduler end to end.
    let bursty = serve::workload::generate(&WorkloadSpec {
        arrival: Arrival::Bursty {
            rate_per_s: 20.0,
            burst_multiplier: 8.0,
            mean_phase_requests: 25.0,
        },
        ..WorkloadSpec::poisson(20.0, 200, 13)
    });
    let (bp, _) = serve::serve_once(&sim, &sys, &model, &cfg, &bursty, &Slo::interactive());
    assert_eq!(bp.summary.requests, 200);
    assert!(bp.summary.throughput_tok_s > 0.0);
}

/// The scheduler-v2 acceptance criterion: on the shipped bursty sample
/// scenario, chunked prefill strictly improves mean TTFT over monolithic
/// execution of the *identical* seeded traffic. Monolithic pays padded
/// whole-prompt batches under backlog (batch padded to the longest
/// prompt, ~2x waste on 128–2048-uniform prompts); chunked processes
/// exact token counts and piggybacks decodes, so the backlog drains
/// faster.
#[test]
fn chunked_improves_mean_ttft_on_bursty_sample_scenario() {
    let mono = serve_scenario("a100-bursty");
    let chunked = serve_scenario("a100-bursty-chunked");
    assert_eq!(
        mono.summary.output_tokens, chunked.summary.output_tokens,
        "the two samples must carry identical traffic"
    );
    assert!(
        chunked.summary.ttft_mean_s < mono.summary.ttft_mean_s,
        "chunked mean TTFT {:.4}s must beat monolithic {:.4}s on the bursty sample",
        chunked.summary.ttft_mean_s,
        mono.summary.ttft_mean_s
    );
    assert!(chunked.stats.mixed_iterations > 0, "chunked run never mixed an iteration");
    // The trade: chunked's decodes ride long iterations, so its token
    // pace cannot beat monolithic's dedicated decode steps by much —
    // sanity-check both produced sane paces rather than degenerate runs.
    assert!(chunked.summary.tpot_mean_s > 0.0 && mono.summary.tpot_mean_s > 0.0);
}

/// Disaggregated sample: phase splitting serves the whole trace, pays
/// transfer latency on every handoff, and keeps both pools inside their
/// KV budgets.
#[test]
fn disaggregated_sample_scenario_serves_with_handoff() {
    let rep = serve_scenario("a100x4-disagg");
    assert_eq!(rep.summary.requests, 48);
    assert!(rep.summary.throughput_tok_s > 0.0);
    assert!(rep.stats.transfer_total_s > 0.0, "no transfers in disaggregated mode");
    assert!(rep.stats.prefill_peak_kv_tokens > 0);
    assert!(rep.stats.prefill_iterations > 0 && rep.stats.decode_iterations > 0);
}

/// Evict sample: the clamped KV budget forces oversubscription; every
/// request still completes and the counters surface in the report.
#[test]
fn evict_sample_scenario_preempts_and_completes() {
    let rep = serve_scenario("a100-evict");
    assert_eq!(rep.summary.requests, 40);
    let total: u64 = rep.summary.output_tokens;
    assert!(total > 0);
    assert!(rep.stats.peak_kv_tokens <= 9_000, "clamped budget exceeded");
    // The clamp is ~3 concurrent full footprints against max_batch 16 and
    // a trace that arrives almost at once — optimistic admission must
    // overshoot at least once.
    assert!(
        rep.stats.preemptions > 0,
        "evict sample produced no preemption (peak {} tokens)",
        rep.stats.peak_kv_tokens
    );
    assert!(rep.stats.recompute_tokens > 0);
}

/// Faulty disaggregated sample: the decode-pool crash with a zero retry
/// budget must lose in-flight requests for good, the link degradation
/// must leave transfers visible, and the under-fault accounting must
/// conserve every submitted request.
#[test]
fn faulty_disagg_sample_loses_requests_but_conserves_accounting() {
    let rep = serve_scenario("a100x4-disagg-faulty");
    let stats = &rep.stats;
    assert_eq!(stats.faults_injected, 2, "both scheduled fault windows must open");
    assert!(stats.requests_lost > 0, "decode crash with max_retries=0 must lose requests");
    assert_eq!(stats.requests_retried, 0, "retry budget is zero");
    assert!(stats.fault_downtime_s > 0.0);
    assert!(
        stats.availability < 1.0,
        "availability {} must reflect the crash window",
        stats.availability
    );
    assert!(stats.transfer_total_s > 0.0);
    assert_eq!(
        rep.summary.requests as u64 + stats.requests_lost + stats.requests_shed,
        48,
        "completed + lost + shed must equal the submitted trace"
    );
}

/// Degraded bursty sample: the slowdown window is not an outage
/// (availability stays 1.0) but admission shedding must refuse part of
/// the thundering herd — refused, never dropped after admission.
#[test]
fn degraded_bursty_sample_sheds_but_never_loses() {
    let rep = serve_scenario("a100-bursty-degraded");
    let stats = &rep.stats;
    assert_eq!(stats.faults_injected, 1);
    assert!(stats.requests_shed > 0, "24-deep shed threshold must refuse part of the burst");
    assert_eq!(stats.requests_lost, 0, "shedding refuses work; it never drops admitted work");
    assert_eq!(stats.availability, 1.0, "a slowdown is degradation, not downtime");
    assert_eq!(rep.summary.requests as u64 + stats.requests_shed, 96);
    // The same traffic without faults completes everything — the shed
    // counter is the only accounting difference.
    let base = serve_scenario("a100-bursty");
    assert_eq!(base.summary.requests, 96);
    assert_eq!(base.stats.requests_shed, 0);
}

/// Fault replay determinism at the scenario level: evaluating the faulty
/// sample twice (fresh simulator each time) must produce byte-identical
/// report JSON — the fault RNG stream is part of the seeded state.
#[test]
fn faulty_scenario_replay_is_byte_identical() {
    let a = serve_scenario("a100x4-disagg-faulty").to_json().to_string_pretty();
    let b = serve_scenario("a100x4-disagg-faulty").to_json().to_string_pretty();
    assert_eq!(a, b, "faulty scenario replay diverged");
}

/// The shipped 4-replica diurnal fleet sample: replica 1 crashes
/// mid-trace and stays down past the end of the trace, so the fleet must
/// re-dispatch its in-flight work to the three survivors, availability
/// must fall strictly below 1.0, and request accounting must conserve —
/// the fleet acceptance criterion, against the scenario CI also smokes.
#[test]
fn fleet_diurnal_sample_survives_replica_crash_with_conservation() {
    let rep = serve_scenario("a100-fleet4-diurnal");
    let stats = &rep.stats;
    assert_eq!(rep.replica_stats.len(), 4, "four replicas must report individually");
    assert_eq!(
        rep.summary.requests as u64 + stats.requests_lost + stats.requests_shed,
        64,
        "completed + lost + shed must equal the submitted trace"
    );
    assert!(
        stats.availability < 1.0,
        "availability {} must reflect the replica-1 outage",
        stats.availability
    );
    assert!(stats.availability > 0.0, "three of four replicas stayed up");
    assert!(stats.requests_retried > 0, "crash victims must re-dispatch to survivors");
    assert!(stats.retry_tokens_recomputed > 0, "re-dispatch re-prefills the lost KV");
    // The surviving replicas actually shared the load.
    let active = rep
        .replica_stats
        .iter()
        .filter(|rs| rs.prefill_iterations + rs.decode_iterations + rs.mixed_iterations > 0)
        .count();
    assert!(active >= 3, "load balancer left survivors idle: {active} active");
    // Fleet replay is byte-identical, diurnal modulation and all.
    let again = serve_scenario("a100-fleet4-diurnal");
    assert_eq!(
        rep.to_json().to_string_pretty(),
        again.to_json().to_string_pretty(),
        "fleet scenario replay diverged"
    );
}

/// Deterministic replay: two runs of the same seeded workload — through
/// the work-stealing hybrid simulator, which exercises the shared worker
/// pool — must produce byte-identical `ServeReport` JSON. Guards the
/// discrete-event queues against ordering nondeterminism.
#[test]
fn deterministic_replay_is_byte_identical() {
    let sys = presets::system("a100x4").unwrap();
    let model = ModelConfig::gpt_small();
    let bursty = WorkloadSpec {
        arrival: Arrival::Bursty { rate_per_s: 30.0, burst_multiplier: 6.0, mean_phase_requests: 20.0 },
        ..WorkloadSpec::poisson(30.0, 120, 23)
    };
    for mode in [
        ServeMode::Monolithic,
        ServeMode::Chunked { chunk_tokens: 1024 },
        ServeMode::Disaggregated { prefill_devices: 1, transfer_base_s: 1e-3 },
    ] {
        let mut cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
        cfg.mode = mode;
        cfg.preemption = Preemption::Evict;
        cfg.kv_capacity_tokens = cfg.kv_capacity_tokens.min(40_000);
        let run = || {
            // A fresh hybrid simulator per run: mapper candidate loops
            // fan over the shared worker pool, which must not leak
            // nondeterminism into the report.
            let sim = Simulator::hybrid();
            let reqs = serve::workload::generate(&bursty);
            let (report, _) = serve::serve_once(&sim, &sys, &model, &cfg, &reqs, &Slo::relaxed());
            report.to_json().to_string_pretty()
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "ServeReport JSON not byte-identical in {:?} mode", mode.name());
    }
}

/// The raw-speed pass's headline number, locked by counters instead of a
/// stopwatch: a 4-replica sweep over two scheduler modes (2 cells × 4
/// engines = 8 engine runs on identical hardware+model) must resolve to
/// ONE shared oracle, so its total analytical-simulator calls stay at
/// ≤ 1/4 of the per-engine baseline where sharing is disabled and every
/// engine re-simulates its own buckets. The counters are deterministic
/// (pure functions of the request mix and pow2 bucketing), so this
/// asserts exact reuse, not a flaky timing ratio.
#[test]
fn shared_oracle_cuts_sweep_simulator_calls_at_least_4x() {
    let model = ModelConfig::gpt_small();
    let mut cfg = serve::sweep::SweepConfig::paper_default(40, Slo::relaxed());
    cfg.systems = vec!["a100x4".into()];
    cfg.modes = vec![ServeMode::Monolithic, ServeMode::Chunked { chunk_tokens: 1024 }];
    cfg.rates = vec![20.0];
    cfg.fleet_sizes = vec![4];

    let shared_sim = Simulator::new();
    let rows = serve::sweep::run_sweep(&shared_sim, &model, &cfg).unwrap();
    assert_eq!(rows.len(), 2, "expected exactly the 2 (mode) cells");
    let shared = shared_sim.oracles.snapshot();

    let private_sim = Simulator::new();
    private_sim.oracles.set_shared(false);
    let private_rows = serve::sweep::run_sweep(&private_sim, &model, &cfg).unwrap();
    let private = private_sim.oracles.snapshot();

    // Correctness first: sharing must not change a byte of any cell.
    for (a, b) in rows.iter().zip(&private_rows) {
        assert_eq!(
            a.summary.to_json().to_string_pretty(),
            b.summary.to_json().to_string_pretty(),
            "shared-oracle sweep diverged from private-oracle sweep"
        );
    }
    // All 8 engine runs share one (hardware, model) fingerprint.
    assert_eq!(shared_sim.oracles.len(), 1, "cells must resolve to one shared oracle");
    assert!(shared.hits > 0, "cross-cell reuse produced no bucket hits");
    assert!(
        shared.sim_calls * 4 <= private.sim_calls,
        "shared oracle made {} simulator calls; per-engine baseline {} is less than 4x that",
        shared.sim_calls,
        private.sim_calls
    );
}

#[test]
fn trace_replay_drives_the_scheduler() {
    let sim = Simulator::new();
    let sys = presets::system("a100").unwrap();
    let model = ModelConfig::gpt_small();
    let cfg = SchedulerConfig::for_system(&sys, &model, Policy::ShortestPromptFirst);
    let text = "0.0,128,16\n0.01,64,8\n0.02,256,4\n";
    let reqs = serve::workload::parse_trace(text).unwrap();
    let (report, per_req) = serve::serve_once(&sim, &sys, &model, &cfg, &reqs, &Slo::relaxed());
    assert_eq!(report.summary.requests, 3);
    assert_eq!(report.summary.output_tokens, 16 + 8 + 4);
    assert!(per_req.iter().all(|m| m.finish_s.is_finite()));
}

#[test]
fn serve_experiment_runs_quick() {
    let ctx = llmcompass::experiments::Ctx::new(true);
    let out = llmcompass::experiments::run("serve", &ctx).unwrap();
    assert!(out.contains("$/1M tok"), "missing cost column:\n{out}");
    assert!(out.contains("throughput-oriented"));
    assert!(out.contains("scheduler-mode comparison"), "missing mode study:\n{out}");
    assert!(out.contains("disaggregated"), "mode study lacks disaggregated:\n{out}");
    assert!(out.contains("SLO under fault"), "missing fault study:\n{out}");
    assert!(out.contains("avail %"), "missing availability column:\n{out}");
    assert!(std::path::Path::new("reports/serve_sweep.csv").exists());
}
