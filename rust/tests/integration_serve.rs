//! Integration: the cluster serving simulator end to end — workload →
//! continuous-batching scheduler → metrics → SLO cost sweep — on real
//! hardware presets, including KV accounting for GPT-3-class models.

use llmcompass::graph::inference::Simulator;
use llmcompass::graph::ModelConfig;
use llmcompass::hardware::presets;
use llmcompass::serve::{
    self, kv_capacity_tokens, Arrival, Policy, SchedulerConfig, Slo, WorkloadSpec,
};

#[test]
fn thousand_requests_complete_with_consistent_accounting() {
    let sim = Simulator::new();
    let sys = presets::system("a100").unwrap();
    let model = ModelConfig::gpt_small();
    let cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
    let reqs = serve::workload::generate(&WorkloadSpec::poisson(30.0, 1000, 42));
    let (summary, stats, per_req) =
        serve::serve_once(&sim, &sys, &model, &cfg, &reqs, &Slo::interactive());

    assert_eq!(summary.requests, 1000);
    let total_out: u64 = reqs.iter().map(|r| r.output_tokens).sum();
    assert_eq!(summary.output_tokens, total_out);
    for (m, r) in per_req.iter().zip(&reqs) {
        assert_eq!(m.id, r.id);
        assert!(m.ttft_s() > 0.0, "request {} TTFT {}", m.id, m.ttft_s());
        assert!(m.e2e_s() >= m.ttft_s());
    }
    // Percentile ordering and conservation.
    assert!(summary.ttft_p50_s <= summary.ttft_p99_s);
    assert!(summary.tpot_p50_s <= summary.tpot_p99_s);
    assert!(summary.goodput_tok_s <= summary.throughput_tok_s + 1e-12);
    assert!((0.0..=1.0).contains(&summary.slo_attainment));
    // The busy/idle split covers the makespan (admission itself is free).
    let accounted = stats.prefill_busy_s + stats.decode_busy_s + stats.idle_s;
    assert!(
        (accounted - stats.makespan_s).abs() < 1e-6 * stats.makespan_s.max(1.0),
        "accounted {accounted:.3} vs makespan {:.3}",
        stats.makespan_s
    );
    assert!(stats.peak_kv_tokens <= cfg.kv_capacity_tokens);
    assert!(stats.peak_batch <= cfg.max_batch);
}

#[test]
fn gpt3_on_a100x8_respects_kv_budget() {
    // GPT-3 on one 8×A100 node: ~290 GB free after weights → ~61k KV
    // tokens. The scheduler must stay under that while still serving.
    let sim = Simulator::new();
    let sys = presets::system("a100x8").unwrap();
    let model = ModelConfig::gpt3_175b();
    let budget = kv_capacity_tokens(&sys, &model);
    assert!((50_000..75_000).contains(&budget), "KV budget {budget}");

    let cfg = SchedulerConfig {
        max_batch: 8,
        kv_capacity_tokens: budget,
        policy: Policy::Fcfs,
        max_prefill_batch: 4,
    };
    let spec = WorkloadSpec {
        arrival: Arrival::Poisson { rate_per_s: 4.0 },
        prompt: serve::LengthDist::Fixed(512),
        output: serve::LengthDist::Fixed(64),
        requests: 50,
        seed: 7,
    };
    let reqs = serve::workload::generate(&spec);
    let (summary, stats, _) = serve::serve_once(&sim, &sys, &model, &cfg, &reqs, &Slo::relaxed());
    assert_eq!(summary.requests, 50);
    assert!(stats.peak_kv_tokens <= budget);
    assert!(stats.peak_kv_tokens >= 8 * (512 + 64), "batch never filled");
    assert!(summary.throughput_tok_s > 0.0);
    // Decode of a GPT-3 batch is milliseconds-per-token territory, not
    // microseconds and not seconds (paper Fig. 11 scale).
    assert!(
        (1e-3..1.0).contains(&summary.tpot_p50_s),
        "TPOT p50 {:.4}s",
        summary.tpot_p50_s
    );
}

#[test]
fn burst_arrivals_queue_worse_than_spaced_arrivals() {
    // Deterministic queueing check: the same 100 requests delivered as one
    // instantaneous burst vs generously spaced. The burst forces later
    // requests to wait behind earlier prefill batches, so mean TTFT must
    // be strictly worse; spacing slower than service keeps queues empty.
    let sim = Simulator::new();
    let sys = presets::system("a100").unwrap();
    let model = ModelConfig::gpt_small();
    let cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
    let mk = |spacing_s: f64| -> Vec<serve::Request> {
        (0..100)
            .map(|i| serve::Request {
                id: i,
                arrival_s: i as f64 * spacing_s,
                prompt_tokens: 512,
                output_tokens: 16,
            })
            .collect()
    };
    let burst = mk(0.0);
    let spaced = mk(0.5);
    let (b, _, _) = serve::serve_once(&sim, &sys, &model, &cfg, &burst, &Slo::interactive());
    let (s, _, _) = serve::serve_once(&sim, &sys, &model, &cfg, &spaced, &Slo::interactive());
    let b_ttft = b.ttft_p50_s + b.ttft_p99_s;
    let s_ttft = s.ttft_p50_s + s.ttft_p99_s;
    assert!(
        b_ttft > s_ttft,
        "burst TTFT (p50+p99) {:.4}s should exceed spaced {:.4}s",
        b_ttft,
        s_ttft
    );
    // The bursty arrival *process* also drives the scheduler end to end.
    let bursty = serve::workload::generate(&WorkloadSpec {
        arrival: Arrival::Bursty {
            rate_per_s: 20.0,
            burst_multiplier: 8.0,
            mean_phase_requests: 25.0,
        },
        ..WorkloadSpec::poisson(20.0, 200, 13)
    });
    let (bp, _, _) = serve::serve_once(&sim, &sys, &model, &cfg, &bursty, &Slo::interactive());
    assert_eq!(bp.requests, 200);
    assert!(bp.throughput_tok_s > 0.0);
}

#[test]
fn trace_replay_drives_the_scheduler() {
    let sim = Simulator::new();
    let sys = presets::system("a100").unwrap();
    let model = ModelConfig::gpt_small();
    let cfg = SchedulerConfig::for_system(&sys, &model, Policy::ShortestPromptFirst);
    let text = "0.0,128,16\n0.01,64,8\n0.02,256,4\n";
    let reqs = serve::workload::parse_trace(text).unwrap();
    let (summary, _, per_req) = serve::serve_once(&sim, &sys, &model, &cfg, &reqs, &Slo::relaxed());
    assert_eq!(summary.requests, 3);
    assert_eq!(summary.output_tokens, 16 + 8 + 4);
    assert!(per_req.iter().all(|m| m.finish_s.is_finite()));
}

#[test]
fn serve_experiment_runs_quick() {
    let ctx = llmcompass::experiments::Ctx::new(true);
    let out = llmcompass::experiments::run("serve", &ctx).unwrap();
    assert!(out.contains("$/1M tok"), "missing cost column:\n{out}");
    assert!(out.contains("throughput-oriented"));
    assert!(std::path::Path::new("reports/serve_sweep.csv").exists());
}
