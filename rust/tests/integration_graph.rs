//! Integration tests for the operator-graph IR refactor.
//!
//! The contract of the refactor: lowering every workload onto the
//! scheduled DAG changes *nothing* for the workloads that existed before
//! it — chain graphs schedule to the serial op walk bit for bit, so the
//! `EvalReport` JSON of layer/request workloads is byte-identical to a
//! from-scratch reconstruction of the pre-refactor arithmetic. And it
//! buys something real: the shipped pipeline-parallel GPT-3 scenario
//! beats the tensor-parallel-only mapping at equal device count.

use llmcompass::eval::{
    EvalReport, EvalResult, Evaluator, Parallelism, Scenario, Workload,
};
use llmcompass::graph::inference::{LayerReport, Simulator};
use llmcompass::graph::layer::{layer_ops, Phase};
use llmcompass::graph::ModelConfig;
use llmcompass::hardware::{presets, SystemSpec};
use std::path::Path;

fn scenarios_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

/// The pre-refactor layer arithmetic, reconstructed from scratch: a
/// serial walk over `layer_ops`, accumulating latency in op order.
fn legacy_layer(sim: &Simulator, sys: &SystemSpec, model: &ModelConfig, phase: Phase) -> LayerReport {
    let ops = layer_ops(model, phase, sys.device_count);
    let mut breakdown = Vec::with_capacity(ops.len());
    let mut total = 0.0f64;
    for nop in &ops {
        let r = sim.op_latency(sys, &nop.op);
        total += r.latency_s;
        breakdown.push((nop.name.to_string(), r.latency_s));
    }
    LayerReport { total_s: total, breakdown }
}

/// The pre-refactor end-to-end request arithmetic: prefill + trapezoid-
/// sampled decode over KV growth, all via the serial layer walk.
fn legacy_e2e(
    sim: &Simulator,
    sys: &SystemSpec,
    model: &ModelConfig,
    batch: u64,
    s_in: u64,
    s_out: u64,
    layers: u64,
) -> f64 {
    let layer = |phase: Phase| legacy_layer(sim, sys, model, phase).total_s;
    let prefill = layers as f64 * layer(Phase::Prefill { batch, seq: s_in });
    let decode = |kv: u64| layers as f64 * layer(Phase::Decode { batch, kv_len: kv });
    if s_out == 0 {
        return prefill;
    }
    let samples = 6usize.min(s_out as usize);
    let decode_sum = if samples <= 2 {
        (1..=s_out).map(|t| decode(s_in + t)).sum()
    } else {
        let mut pts: Vec<(f64, f64)> = Vec::with_capacity(samples);
        for i in 0..samples {
            let t = 1 + (s_out - 1) * i as u64 / (samples as u64 - 1);
            pts.push((t as f64, decode(s_in + t)));
        }
        let mut sum = 0.0;
        for w in pts.windows(2) {
            let (t0, l0) = w[0];
            let (t1, l1) = w[1];
            sum += (t1 - t0) * (l0 + l1) / 2.0;
        }
        sum + (pts[0].1 + pts[pts.len() - 1].1) / 2.0
    };
    prefill + decode_sum
}

#[test]
fn layer_reports_byte_identical_to_pre_refactor_path_on_designs_a_to_e() {
    let model = "gpt-small";
    let m = ModelConfig::by_name(model).unwrap();
    for letter in ['A', 'B', 'C', 'D', 'E'] {
        let hw = format!("design-{letter}x2");
        let sys = presets::system(&hw).unwrap();
        for phase in [
            Phase::Prefill { batch: 2, seq: 128 },
            Phase::Decode { batch: 4, kv_len: 256 },
        ] {
            let sc = Scenario::new(
                "layer-id",
                &hw,
                Workload::Layer { model: model.into(), phase },
            );
            let ev = Evaluator::new();
            let rep = ev.evaluate(&sc).unwrap();
            // Reconstruct the whole report with pre-refactor arithmetic
            // (reusing the evaluator's simulator so mapper results are
            // the same memoized values) and demand byte equality.
            let legacy = EvalReport {
                scenario: sc.clone(),
                system: sys.clone(),
                results: vec![EvalResult::LayerLatency {
                    layers: m.layers,
                    per_layer: legacy_layer(&ev.sim, &sys, &m, phase),
                }],
                // Self-profiling is not under test (wall time can never be
                // byte-equal); carry the evaluated report's section.
                telemetry: rep.telemetry.clone(),
            };
            assert_eq!(
                rep.to_json().to_string_pretty(),
                legacy.to_json().to_string_pretty(),
                "design {letter} {phase:?}: graph lowering drifted from the serial walk"
            );
        }
    }
}

#[test]
fn request_reports_byte_identical_to_pre_refactor_path_on_designs_a_to_e() {
    let model = "gpt-small";
    let m = ModelConfig::by_name(model).unwrap();
    for letter in ['A', 'B', 'C', 'D', 'E'] {
        let hw = format!("design-{letter}x2");
        let sys = presets::system(&hw).unwrap();
        let (batch, s_in, s_out, layers) = (2u64, 64u64, 8u64, 3u64);
        let sc = Scenario::new(
            "req-id",
            &hw,
            Workload::Request {
                model: model.into(),
                batch,
                prefill: s_in,
                decode: s_out,
                layers: Some(layers),
            },
        );
        let ev = Evaluator::new();
        let rep = ev.evaluate(&sc).unwrap();
        let total = legacy_e2e(&ev.sim, &sys, &m, batch, s_in, s_out, layers);
        let legacy = EvalReport {
            scenario: sc.clone(),
            system: sys.clone(),
            results: vec![EvalResult::RequestLatency {
                total_s: total,
                tokens_per_s_per_request: s_out as f64 / total,
            }],
            // Self-profiling is not under test (wall time can never be
            // byte-equal); carry the evaluated report's section.
            telemetry: rep.telemetry.clone(),
        };
        assert_eq!(
            rep.to_json().to_string_pretty(),
            legacy.to_json().to_string_pretty(),
            "design {letter}: request lowering drifted from the serial walk"
        );
    }
}

#[test]
fn shipped_pp4_scenario_beats_tp_only_at_equal_device_count() {
    // The acceptance criterion of the IR refactor: on the shipped
    // pipeline-parallel GPT-3 sample (4 A100s on a PCIe-class host
    // fabric), {tp:1, pp:4, mb:8} strictly beats {tp:4, pp:1} — the
    // per-layer all-reduces of tensor parallelism cost more than the
    // pipeline's per-microbatch boundary handoffs plus its fill/drain
    // bubbles.
    let path = scenarios_dir().join("gpt3_pp4_request.json");
    let sc = Scenario::load(&path).unwrap();
    assert_eq!(sc.parallelism, Some(Parallelism { tp: 1, pp: 4, microbatches: 8 }));
    let ev = Evaluator::new();
    let total = |rep: &EvalReport| match &rep.results[0] {
        EvalResult::RequestLatency { total_s, .. } => *total_s,
        _ => panic!("expected request latency"),
    };
    let pp = total(&ev.evaluate(&sc).unwrap());
    let tp_only = sc.clone().with_parallelism(Parallelism { tp: 4, pp: 1, microbatches: 1 });
    let tp = total(&ev.evaluate(&tp_only).unwrap());
    assert!(
        pp < tp,
        "pipeline parallelism should win on a PCIe fabric: pp {pp:.3}s vs tp {tp:.3}s"
    );
}

#[test]
fn shipped_branchy_graph_scenario_schedules() {
    let path = scenarios_dir().join("branchy_residual_graph.json");
    let sc = Scenario::load(&path).unwrap();
    let ev = Evaluator::new();
    let rep = ev.evaluate(&sc).unwrap();
    let EvalResult::GraphLatency { schedule } = &rep.results[0] else {
        panic!("expected a graph schedule")
    };
    // 7 workload nodes + the tp=2 all-reduce appended after the sink.
    assert_eq!(schedule.timings.len(), 8);
    assert!(schedule.total_s > 0.0);
    assert!(schedule.total_s >= schedule.critical_path_s);
    assert!(schedule.total_s <= schedule.serial_s * (1.0 + 1e-12));
    // The all-reduce exists and runs on the interconnect resource.
    let ar = schedule.timings.iter().find(|t| t.name == "AllReduce_ln_out").unwrap();
    assert!(ar.comm);
    // Everything still round-trips through the report JSON.
    let j = rep.to_json();
    assert_eq!(
        j.get("results")
            .and_then(|r| r.get("latency"))
            .and_then(|l| l.get("kind"))
            .and_then(llmcompass::util::json::Json::as_str),
        Some("graph")
    );
}

#[test]
fn graph_tensor_parallel_shrinks_the_schedule_on_the_shipped_sample() {
    // tp=2 halves every matmul's work; even with the extra all-reduce
    // the branchy block must run faster than unsharded on one device.
    let path = scenarios_dir().join("branchy_residual_graph.json");
    let sharded = Scenario::load(&path).unwrap();
    let mut unsharded = sharded.clone();
    unsharded.parallelism = None;
    unsharded.hardware = "a100".into();
    let ev = Evaluator::new();
    let total = |sc: &Scenario| match &ev.evaluate(sc).unwrap().results[0] {
        EvalResult::GraphLatency { schedule } => schedule.total_s,
        _ => panic!("expected graph latency"),
    };
    assert!(total(&sharded) < total(&unsharded));
}
