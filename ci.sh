#!/usr/bin/env bash
# Tier-1 gate + formatting, as run by CI (.github/workflows/ci.yml).
#
#   ./ci.sh          # build, test, fmt-check
#   ./ci.sh --fix    # also apply `cargo fmt` instead of just checking
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Explicit doc-test pass: `cargo test` covers lib doctests too, but this
# keeps them gated even when someone filters the unit/integration suites.
echo "== cargo test --doc -q =="
cargo test --doc -q

# Golden-report regression gate, explicitly: every scenarios/*.json must
# parse as a valid Scenario and evaluate to its checked-in EvalReport
# (field-by-field, float-tolerant). GOLDEN_UPDATE=1 regenerates goldens.
echo "== cargo test --test integration_golden =="
cargo test --test integration_golden

# Scenario-suite smoke through the real CLI: catches scenario-schema and
# CLI-surface drift (flag parsing, suite fan-out, report emission) that
# in-process unit tests miss.
echo "== llmcompass eval --suite ../scenarios =="
target/release/llmcompass eval --suite ../scenarios --compact > /dev/null

# Telemetry smoke: a --trace run must write Chrome trace-event JSON that
# parses and carries at least one event.
echo "== llmcompass eval --trace =="
target/release/llmcompass eval --scenario ../scenarios/a100_bursty.json \
    --trace /tmp/llmcompass_trace.json > /dev/null
if command -v python3 > /dev/null 2>&1; then
    python3 -c '
import json
events = json.load(open("/tmp/llmcompass_trace.json"))["traceEvents"]
assert len(events) >= 1, "trace has no events"
print(f"trace OK: {len(events)} events")
'
else
    # No python3: at least require a non-empty event list in the output.
    grep -q '"ph"' /tmp/llmcompass_trace.json \
        || { echo "trace has no events" >&2; exit 1; }
fi

# Tune smoke through the real CLI: a tiny design-space search (the
# `smoke` preset: 2 core counts x 2 memories around the A100) over the
# bursty sample must emit a valid TuneReport with at least one frontier
# point and a best design.
echo "== llmcompass tune --space smoke =="
target/release/llmcompass tune --scenario ../scenarios/a100_bursty.json \
    --space smoke > /tmp/llmcompass_tune.json
if command -v python3 > /dev/null 2>&1; then
    python3 -c '
import json
rep = json.load(open("/tmp/llmcompass_tune.json"))
assert rep["schema_version"] == 1, "unexpected tune schema version"
frontier = rep["frontier"]
assert len(frontier) >= 1, "tune frontier is empty"
best = rep.get("best")
assert best, "tune produced no best design"
print(f"tune OK: {len(frontier)} frontier point(s), best " + best["name"])
'
else
    # No python3: at least require a non-empty frontier in the output.
    grep -q '"frontier"' /tmp/llmcompass_tune.json \
        || { echo "tune report has no frontier" >&2; exit 1; }
fi

# Fault-injection smoke through the real CLI: a crash+drain spec with a
# zero retry budget must complete the run with requests actually lost,
# availability strictly below 1.0, and conserved accounting
# (completed + lost + shed == submitted). Exercises the --fault-spec
# flag end to end, including the parseable `faults:` stats line.
echo "== llmcompass serve --fault-spec (crash + drain) =="
cat > /tmp/llmcompass_faults.json <<'EOF'
{
  "seed": 5,
  "events": [
    {"kind": "crash", "at_s": 0.05, "duration_s": 0.4},
    {"kind": "drain", "at_s": 1.0, "duration_s": 0.5}
  ],
  "recovery": {"max_retries": 0}
}
EOF
target/release/llmcompass serve --hardware a100 --model gpt-small \
    --requests 60 --rate 80 --seed 42 \
    --fault-spec /tmp/llmcompass_faults.json | tee /tmp/llmcompass_fault_smoke.txt
if command -v python3 > /dev/null 2>&1; then
    python3 -c '
import re
out = open("/tmp/llmcompass_fault_smoke.txt").read()
faults = re.search(r"faults: injected=(\d+) lost=(\d+) retried=(\d+) shed=(\d+) "
                   r"retry_tokens_recomputed=(\d+) downtime_s=([\d.]+) "
                   r"availability=([\d.]+)", out)
assert faults, "no parseable faults line in serve output"
injected, lost, retried, shed = (int(faults.group(i)) for i in range(1, 5))
availability = float(faults.group(7))
completed = int(re.search(r"^requests (\d+) \|", out, re.M).group(1))
assert injected >= 2, f"both fault windows must open, got {injected}"
assert lost > 0, "crash with max_retries=0 must lose requests"
assert availability < 1.0, f"availability {availability} must reflect downtime"
assert completed + lost + shed == 60, \
    f"accounting leak: {completed} completed + {lost} lost + {shed} shed != 60"
print(f"fault smoke OK: {completed} completed, {lost} lost, "
      f"{shed} shed, availability {availability}")
'
else
    # No python3: at least require the faults line with nonzero loss and
    # sub-1.0 availability.
    grep -Eq "faults: injected=[0-9]+ lost=[1-9]" /tmp/llmcompass_fault_smoke.txt \
        || { echo "fault smoke lost no requests" >&2; exit 1; }
    grep -Eq "availability=0\." /tmp/llmcompass_fault_smoke.txt \
        || { echo "fault smoke shows no downtime" >&2; exit 1; }
fi

# Fleet smoke through the real CLI: four data-parallel replicas behind
# the least-KV-pressure balancer under bursty traffic, with replica 1
# crashing mid-trace and staying down. The run must complete with
# conserved accounting (completed + lost + shed == submitted),
# availability strictly below 1.0, and the key=value stats line (plus
# the per-replica lines) still parseable.
echo "== llmcompass serve --replicas 4 --balancer least_kv_pressure (replica crash) =="
cat > /tmp/llmcompass_fleet_faults.json <<'EOF'
{
  "seed": 11,
  "events": [
    {"kind": "crash", "at_s": 0.3, "duration_s": 30.0, "target": "replica:1"}
  ],
  "recovery": {"max_retries": 2, "retry_backoff_s": 0.05}
}
EOF
target/release/llmcompass serve --hardware a100 --model gpt-small \
    --requests 64 --rate 30 --arrival bursty --burst-mult 6 --seed 42 \
    --replicas 4 --balancer least_kv_pressure \
    --fault-spec /tmp/llmcompass_fleet_faults.json | tee /tmp/llmcompass_fleet_smoke.txt
if command -v python3 > /dev/null 2>&1; then
    python3 -c '
import re
out = open("/tmp/llmcompass_fleet_smoke.txt").read()
faults = re.search(r"faults: injected=(\d+) lost=(\d+) retried=(\d+) shed=(\d+) "
                   r"retry_tokens_recomputed=(\d+) downtime_s=([\d.]+) "
                   r"availability=([\d.]+)", out)
assert faults, "no parseable faults line in fleet serve output"
lost, retried, shed = (int(faults.group(i)) for i in (2, 3, 4))
availability = float(faults.group(7))
completed = int(re.search(r"^requests (\d+) \|", out, re.M).group(1))
replicas = re.findall(r"^replica (\d+):", out, re.M)
assert replicas == ["0", "1", "2", "3"], f"expected 4 replica lines, got {replicas}"
assert availability < 1.0, f"availability {availability} must reflect the replica outage"
assert completed + lost + shed == 64, \
    f"fleet accounting leak: {completed} completed + {lost} lost + {shed} shed != 64"
print(f"fleet smoke OK: {completed} completed, {lost} lost, {shed} shed, "
      f"{retried} retried, availability {availability}")
'
else
    # No python3: at least require 4 replica lines and sub-1.0 availability.
    [[ "$(grep -cE '^replica [0-9]+:' /tmp/llmcompass_fleet_smoke.txt)" == "4" ]] \
        || { echo "fleet smoke missing per-replica lines" >&2; exit 1; }
    grep -Eq "availability=0\." /tmp/llmcompass_fleet_smoke.txt \
        || { echo "fleet smoke shows no downtime" >&2; exit 1; }
fi

# Sweep smoke through the real CLI: a 2-fleet-size x 2-mode sweep on one
# system, where every cell shares the same (hardware, model) latency
# oracle. The parseable `oracle:` stats line must show cross-cell reuse
# (hits > 0) against exactly one cached oracle — the raw-speed pass's
# sharing, observable from the outside.
echo "== llmcompass serve --sweep (shared oracle across cells) =="
target/release/llmcompass serve --sweep --model gpt-small \
    --requests 40 --seed 42 \
    --systems a100x4 --modes monolithic,chunked --fleet-sizes 1,4 \
    | tee /tmp/llmcompass_sweep_smoke.txt
if command -v python3 > /dev/null 2>&1; then
    python3 -c '
import re
out = open("/tmp/llmcompass_sweep_smoke.txt").read()
oracle = re.search(r"oracle: sim_calls=(\d+) hits=(\d+) misses=(\d+) "
                   r"decode_fits=(\d+) prefill_points=(\d+) oracles=(\d+)", out)
assert oracle, "no parseable oracle line in sweep output"
sim_calls, hits, misses, fits, points, oracles = (int(oracle.group(i)) for i in range(1, 7))
assert oracles == 1, f"identical cells must share one oracle, got {oracles}"
assert hits > 0, "sweep cells produced no cross-cell oracle hits"
assert hits > misses, f"a warm sweep must hit more than it misses ({hits} vs {misses})"
assert sim_calls == 2 * fits + points, \
    f"counter identity broken: {sim_calls} != 2*{fits} + {points}"
print(f"sweep smoke OK: {hits} hits / {misses} misses, "
      f"{sim_calls} simulator calls into {oracles} oracle(s)")
'
else
    # No python3: at least require the oracle line with nonzero hits and
    # a single cached oracle.
    grep -Eq "oracle: sim_calls=[0-9]+ hits=[1-9]" /tmp/llmcompass_sweep_smoke.txt \
        || { echo "sweep smoke shows no oracle hits" >&2; exit 1; }
    grep -Eq "oracles=1$" /tmp/llmcompass_sweep_smoke.txt \
        || { echo "sweep smoke cells did not share one oracle" >&2; exit 1; }
fi

# The shipped faulty samples run through the suite smoke above; run the
# serving/property fault suites explicitly so a filtered `cargo test`
# invocation can never skip them.
echo "== cargo test --test integration_serve --test property_serve =="
cargo test -q --test integration_serve --test property_serve

if [[ "${1:-}" == "--fix" ]]; then
    echo "== cargo fmt =="
    cargo fmt
else
    echo "== cargo fmt --check =="
    cargo fmt --check
fi

echo "ci.sh: all green"
