#!/usr/bin/env bash
# Tier-1 gate + formatting, as run by CI (.github/workflows/ci.yml).
#
#   ./ci.sh          # build, test, fmt-check
#   ./ci.sh --fix    # also apply `cargo fmt` instead of just checking
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Explicit doc-test pass: `cargo test` covers lib doctests too, but this
# keeps them gated even when someone filters the unit/integration suites.
echo "== cargo test --doc -q =="
cargo test --doc -q

# Golden-report regression gate, explicitly: every scenarios/*.json must
# parse as a valid Scenario and evaluate to its checked-in EvalReport
# (field-by-field, float-tolerant). GOLDEN_UPDATE=1 regenerates goldens.
echo "== cargo test --test integration_golden =="
cargo test --test integration_golden

# Scenario-suite smoke through the real CLI: catches scenario-schema and
# CLI-surface drift (flag parsing, suite fan-out, report emission) that
# in-process unit tests miss.
echo "== llmcompass eval --suite ../scenarios =="
target/release/llmcompass eval --suite ../scenarios --compact > /dev/null

# Telemetry smoke: a --trace run must write Chrome trace-event JSON that
# parses and carries at least one event.
echo "== llmcompass eval --trace =="
target/release/llmcompass eval --scenario ../scenarios/a100_bursty.json \
    --trace /tmp/llmcompass_trace.json > /dev/null
if command -v python3 > /dev/null 2>&1; then
    python3 -c '
import json
events = json.load(open("/tmp/llmcompass_trace.json"))["traceEvents"]
assert len(events) >= 1, "trace has no events"
print(f"trace OK: {len(events)} events")
'
else
    # No python3: at least require a non-empty event list in the output.
    grep -q '"ph"' /tmp/llmcompass_trace.json \
        || { echo "trace has no events" >&2; exit 1; }
fi

# Tune smoke through the real CLI: a tiny design-space search (the
# `smoke` preset: 2 core counts x 2 memories around the A100) over the
# bursty sample must emit a valid TuneReport with at least one frontier
# point and a best design.
echo "== llmcompass tune --space smoke =="
target/release/llmcompass tune --scenario ../scenarios/a100_bursty.json \
    --space smoke > /tmp/llmcompass_tune.json
if command -v python3 > /dev/null 2>&1; then
    python3 -c '
import json
rep = json.load(open("/tmp/llmcompass_tune.json"))
assert rep["schema_version"] == 1, "unexpected tune schema version"
frontier = rep["frontier"]
assert len(frontier) >= 1, "tune frontier is empty"
best = rep.get("best")
assert best, "tune produced no best design"
print(f"tune OK: {len(frontier)} frontier point(s), best " + best["name"])
'
else
    # No python3: at least require a non-empty frontier in the output.
    grep -q '"frontier"' /tmp/llmcompass_tune.json \
        || { echo "tune report has no frontier" >&2; exit 1; }
fi

if [[ "${1:-}" == "--fix" ]]; then
    echo "== cargo fmt =="
    cargo fmt
else
    echo "== cargo fmt --check =="
    cargo fmt --check
fi

echo "ci.sh: all green"
