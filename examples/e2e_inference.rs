//! End-to-end driver: all three layers composing on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_inference
//! ```
//!
//! 1. Loads the AOT-compiled gpt-mini model (Pallas kernels → JAX → HLO
//!    text) into the PJRT CPU runtime — Python is not involved.
//! 2. Serves a synthetic batched request trace through the Layer-3
//!    coordinator, reporting per-request latency and aggregate throughput.
//! 3. Calibrates a CPU device description from operator micro-probes and
//!    compares the *measured* serving throughput with what the LLMCompass
//!    performance model *predicts* for the same model on that description —
//!    the paper's Fig. 5h–l experiment, end to end, on hardware we own.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use llmcompass::calibrate;
use llmcompass::coordinator::{queue, Coordinator};
use llmcompass::graph::layer::Phase;
use llmcompass::graph::{inference::Simulator, ModelConfig};
use llmcompass::hardware::{DType, SystemSpec};
use llmcompass::runtime::Runtime;
use llmcompass::util::fmt_seconds;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts found — run `make artifacts` first");
        std::process::exit(1);
    }

    // --- serve a batched trace through the coordinator -------------------
    let mut coord = Coordinator::new(dir)?;
    let model_meta = {
        let rt = Runtime::new(dir)?;
        rt.manifest().model.clone()
    };
    println!(
        "model: gpt-mini ({} layers, d={}, {} heads, vocab {}, {:.1}M params) on PJRT CPU",
        model_meta.layers,
        model_meta.d_model,
        model_meta.heads,
        model_meta.vocab,
        model_meta.n_params as f64 / 1e6
    );
    let n_req = 8;
    let max_out = 8;
    let trace = queue::synthetic_trace(n_req, coord.vocab() as i32, coord.prefill_seq, max_out, 7);
    println!(
        "serving {n_req} requests, batch={}, prompt={} tokens, ≤{max_out} output tokens…",
        coord.batch, coord.prefill_seq
    );
    let rep = coord.serve(&trace)?;
    let decode_steps: u64 = rep.tokens_generated;
    println!(
        "measured: {} tokens in {:.2}s → {:.2} tok/s | prefill {:.2}s, decode {:.2}s | p50 {:.2}s p95 {:.2}s",
        rep.tokens_generated,
        rep.total_s,
        rep.tokens_per_s(),
        rep.prefill_s,
        rep.decode_s,
        rep.latency_percentile(50.0),
        rep.latency_percentile(95.0)
    );

    // --- predict the same workload with the performance model -------------
    println!("\ncalibrating CPU device description from operator micro-probes…");
    let mut rt = Runtime::new(dir)?;
    let meas = calibrate::measure_operators(&mut rt, 2)?;
    let dev = calibrate::tune_cpu_device(
        calibrate::fit_cpu_device(&meas, llmcompass::util::pool::default_threads() as u64),
        &meas,
    );
    let sys = SystemSpec::single(dev);
    let sim = Simulator::new();
    let model = ModelConfig {
        name: "gpt-mini".into(),
        layers: model_meta.layers,
        d_model: model_meta.d_model,
        heads: model_meta.heads,
        d_ff: model_meta.d_ff,
        vocab: model_meta.vocab,
        dtype: DType::FP32,
        ..ModelConfig::gpt_small()
    };
    let batches = (n_req as u64).div_ceil(coord.batch as u64);
    let pre_s = sim.prefill(&sys, &model, coord.batch as u64, coord.prefill_seq as u64, model.layers);
    let dec_s = sim.decode(
        &sys,
        &model,
        coord.batch as u64,
        coord.prefill_seq as u64 + max_out as u64 / 2,
        model.layers,
    );
    let predicted_total = batches as f64 * (pre_s + max_out as f64 * dec_s);
    let predicted_tps = decode_steps as f64 / predicted_total;
    println!(
        "predicted: prefill {}/batch, decode {}/token → {:.2} tok/s",
        fmt_seconds(pre_s),
        fmt_seconds(dec_s),
        predicted_tps
    );
    let ratio = rep.tokens_per_s() / predicted_tps;
    println!(
        "measured/predicted throughput ratio: {ratio:.2} (1.0 = perfect; paper-style \
         validation, see EXPERIMENTS.md)"
    );

    // --- simulate the same serving scenario at datacenter scale -----------
    let gpt3 = ModelConfig::gpt3_175b();
    let a100x4 = llmcompass::hardware::presets::system("a100x4").unwrap();
    let pre = sim.layer(&a100x4, &gpt3, Phase::Prefill { batch: 8, seq: 2048 }).total_s;
    println!(
        "\nfor scale: the same simulator puts one GPT-3 layer prefill (b=8, s=2048) on \
         4xA100 at {} — {}x the gpt-mini stack on this CPU",
        fmt_seconds(pre),
        (pre_s / pre) as u64
    );
    Ok(())
}
