//! Quickstart: the LLMCompass library API in one file.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the full evaluation loop the paper describes: describe hardware →
//! simulate operators and Transformer phases → inspect area and cost.

use llmcompass::area;
use llmcompass::cost::{device_cost, CostParams};
use llmcompass::graph::layer::Phase;
use llmcompass::graph::{inference::Simulator, ModelConfig};
use llmcompass::hardware::{presets, DType};
use llmcompass::perf::Op;
use llmcompass::util::fmt_seconds;

fn main() {
    // 1. Describe hardware — presets cover Table I; any field is editable.
    let sys = presets::system("a100x4").expect("preset");
    println!(
        "system: 4x {} — {:.0} TFLOPS FP16 matrix, {:.1} TB/s HBM each",
        sys.device.name,
        sys.device.peak_matrix_flops() / 1e12,
        sys.device.memory.bandwidth_bytes_per_s / 1e12
    );

    // 2. Simulate a single operator: the mapper searches tilings/schedules.
    let sim = Simulator::new();
    let gemm = Op::Matmul { b: 1, m: 2048, k: 12288, n: 12288, dtype: DType::FP16, batched_b: false };
    let r = sim.op_latency(&sys, &gemm);
    println!(
        "\nGEMM 2048x12288x12288 fp16: {} ({:.0}% of roofline, {} mapper rounds)\n  best mapping: {}",
        fmt_seconds(r.latency_s),
        r.roofline_fraction() * 100.0,
        r.mapper_rounds,
        r.mapping_desc
    );

    // 3. Simulate a GPT-3 layer in both inference phases (paper Fig. 2).
    let gpt3 = ModelConfig::gpt3_175b();
    let prefill = sim.layer(&sys, &gpt3, Phase::Prefill { batch: 8, seq: 2048 });
    let decode = sim.layer(&sys, &gpt3, Phase::Decode { batch: 8, kv_len: 3072 });
    println!(
        "\nGPT-3 layer (b=8, s=2048, TP=4): prefill {} | decode {}/token",
        fmt_seconds(prefill.total_s),
        fmt_seconds(decode.total_s)
    );
    println!("top prefill ops:");
    let mut ops = prefill.breakdown.clone();
    ops.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (name, s) in ops.iter().take(3) {
        println!("  {name:<14} {}", fmt_seconds(*s));
    }

    // 4. End-to-end request latency (decode integrated over KV growth).
    let e2e = sim.e2e_latency(&sys, &gpt3, 8, 2048, 256, gpt3.layers);
    println!("\nfull GPT-3, in=2048, out=256, b=8: {}", fmt_seconds(e2e));

    // 5. Area and cost (paper §III-D).
    let dev = presets::a100();
    let breakdown = area::die_breakdown(&area::AreaParams::default(), &dev, 600e9);
    let cost = device_cost(&CostParams::default(), &dev);
    println!(
        "\n{}: modeled die {:.0} mm² (cores {:.0} mm²), die ${:.0} + memory ${:.0} = ${:.0}",
        dev.name,
        breakdown.total_mm2(),
        breakdown.core_total_mm2(),
        cost.die_cost_usd,
        cost.memory_cost_usd,
        cost.total_usd()
    );
    println!("\nNext: `llmcompass experiment --list` regenerates every paper figure/table.");
}
