//! Cost explorer: Table IV economics for every preset plus what-if memory
//! configurations — the §V "efficient hardware design" workflow.
//!
//! ```bash
//! cargo run --release --example cost_explorer
//! ```

use llmcompass::area::die_mm2;
use llmcompass::cost::{device_cost, dies_per_wafer, murphy_yield, CostParams};
use llmcompass::hardware::{presets, MemProtocol};
use llmcompass::util::table::Table;

fn main() {
    let p = CostParams::default();

    let mut t = Table::new(&[
        "device", "die mm²", "yield %", "dies/wafer", "die $", "memory $", "total $",
    ])
    .with_title("device economics (wafer $9346, 7nm-class, Murphy yield)");
    for name in presets::all_device_names() {
        if name == "tpuv3" {
            // The paper's TPUv3 description folds HBM into the global
            // buffer (Table I), so the SRAM area model does not apply.
            continue;
        }
        let dev = presets::device(name).unwrap();
        let c = device_cost(&p, &dev);
        t.row(vec![
            name.to_string(),
            format!("{:.0}", c.die_mm2),
            format!("{:.1}", murphy_yield(&p, c.die_mm2) * 100.0),
            format!("{:.0}", dies_per_wafer(&p, c.die_mm2)),
            format!("{:.0}", c.die_cost_usd),
            format!("{:.0}", c.memory_cost_usd),
            format!("{:.0}", c.total_usd()),
        ]);
    }
    println!("{}", t.render());

    // What-if: GA100 compute die with different memory systems.
    let mut t = Table::new(&["memory system", "BW TB/s", "capacity GB", "memory $", "$ / (GB/s)"])
        .with_title("what-if: memory system alternatives for a GA100-class die");
    for (label, proto, bw, cap) in [
        ("HBM2e x5 (A100)", MemProtocol::HBM2E, 2.0, 80.0),
        ("HBM2e x6 (full)", MemProtocol::HBM2E, 2.4, 96.0),
        ("DDR5 + PCIe5/CXL (paper §V-B)", MemProtocol::PCIE5CXL, 1.0, 512.0),
        ("DDR5 direct", MemProtocol::DDR5, 0.4, 256.0),
    ] {
        let mut dev = presets::ga100();
        dev.memory.protocol = proto;
        dev.memory.bandwidth_bytes_per_s = bw * 1e12;
        dev.memory.capacity_bytes = (cap * 1e9) as u64;
        let mem = llmcompass::cost::memory_cost_usd(&p, &dev);
        t.row(vec![
            label.to_string(),
            format!("{bw:.1}"),
            format!("{cap:.0}"),
            format!("{mem:.0}"),
            format!("{:.2}", mem / (bw * 1000.0)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper §V-B: trading bandwidth for capacity (HBM → DRAM) costs 2x decode latency \
         but buys >12x batch — 3.41x perf/cost. Run `llmcompass experiment tab4` for the \
         full reproduction."
    );

    let _ = die_mm2(&presets::a100());
}
