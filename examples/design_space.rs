//! Design-space exploration: sweep core count × memory bandwidth over the
//! GA100 template, evaluate GPT-3 prefill/decode and perf-per-cost, and
//! print the Pareto frontier — the §IV/§V workflow as a library user would
//! script it.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use llmcompass::area::die_mm2;
use llmcompass::cost::{die_cost_usd, memory_cost_usd, CostParams};
use llmcompass::graph::layer::Phase;
use llmcompass::graph::{inference::Simulator, ModelConfig};
use llmcompass::hardware::{presets, InterconnectSpec, SystemSpec};
use llmcompass::util::table::Table;

#[derive(Clone)]
struct Point {
    cores: u64,
    bw_tbs: f64,
    prefill_ms: f64,
    decode_ms: f64,
    cost: f64,
    perf_per_dollar: f64,
}

fn main() {
    let sim = Simulator::new();
    let model = ModelConfig::gpt3_175b();
    let costp = CostParams::default();

    let mut points: Vec<Point> = Vec::new();
    for &cores in &[32u64, 64, 96, 128] {
        for &bw in &[1.0f64, 1.5, 2.0, 3.0] {
            let mut dev = presets::ga100();
            dev.name = format!("ga100-c{cores}-bw{bw}");
            dev.core_count = cores;
            dev.memory.bandwidth_bytes_per_s = bw * 1e12;
            let area = die_mm2(&dev);
            let cost = die_cost_usd(&costp, area) + memory_cost_usd(&costp, &dev);
            let sys = SystemSpec {
                device: dev,
                device_count: 4,
                interconnect: InterconnectSpec::nvlink_like(600e9),
            };
            let pre = sim.layer(&sys, &model, Phase::Prefill { batch: 8, seq: 2048 }).total_s;
            let dec = sim.layer(&sys, &model, Phase::Decode { batch: 8, kv_len: 3072 }).total_s;
            // Perf: inverse of a 2048-in/256-out request latency proxy.
            let req = pre + 256.0 * dec;
            points.push(Point {
                cores,
                bw_tbs: bw,
                prefill_ms: pre * 1e3,
                decode_ms: dec * 1e3,
                cost,
                perf_per_dollar: 1.0 / (req * cost),
            });
        }
    }

    let mut t = Table::new(&["cores", "BW TB/s", "prefill ms", "decode ms", "cost $", "perf/$ (norm)", "pareto"])
        .with_title("design space: GA100 template, core count x memory bandwidth (per GPT-3 layer, TP=4)");
    let best_ppd = points.iter().map(|p| p.perf_per_dollar).fold(0.0, f64::max);
    for p in &points {
        // Pareto: no other point is strictly better in (latency, cost).
        let req = p.prefill_ms + 256.0 * p.decode_ms;
        let dominated = points.iter().any(|q| {
            let qreq = q.prefill_ms + 256.0 * q.decode_ms;
            qreq < req && q.cost < p.cost
        });
        t.row(vec![
            p.cores.to_string(),
            format!("{:.1}", p.bw_tbs),
            format!("{:.1}", p.prefill_ms),
            format!("{:.3}", p.decode_ms),
            format!("{:.0}", p.cost),
            format!("{:.2}", p.perf_per_dollar / best_ppd),
            if dominated { "" } else { "*" }.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "* = Pareto-optimal in (request latency, cost). Paper §V: pruning compute \
         (fewer cores) keeps decode flat — visible in the decode column."
    );
    println!("mapper: {} rounds across {} unique shapes", sim.mapper.total_rounds(), sim.mapper.cache_len());
}
