//! The unified `eval` API: build a scenario in code, round-trip it
//! through JSON, and evaluate the shipped `scenarios/` suite with a
//! shared mapper cache.
//!
//! Run: `cargo run --release --example eval_scenarios`

use llmcompass::eval::{self, Evaluator, Output, Scenario, Workload};
use llmcompass::graph::layer::Phase;

fn main() -> Result<(), String> {
    let ev = Evaluator::new();

    // 1. Builder-constructed: one GPT-3 prefill layer on a 4xA100 node,
    //    with the device cost riding along.
    let sc = Scenario::new(
        "prefill-layer",
        "a100x4",
        Workload::Layer {
            model: "gpt3-175b".into(),
            phase: Phase::Prefill { batch: 8, seq: 2048 },
        },
    )
    .with_output(Output::Cost);
    let rep = ev.evaluate(&sc)?;
    print!("{}", rep.to_json().to_string_pretty());

    // 2. The same scenario survives a JSON round trip bit-for-bit.
    let again = Scenario::parse(&sc.to_json().to_string_pretty())?;
    assert_eq!(sc, again, "scenario JSON round trip must be lossless");

    // 3. The shipped suite, fanned across the pool. The evaluator is the
    //    same one as above, so every already-searched shape is a cache hit.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios");
    let suite = eval::load_suite(&dir)?;
    let reports = ev.evaluate_suite(&suite, llmcompass::util::pool::default_threads());
    println!("\nsuite of {} scenarios:", suite.len());
    for (sc, rep) in suite.iter().zip(&reports) {
        match rep {
            Ok(r) => println!("  {:<24} {} output(s) evaluated", sc.name, r.results.len()),
            Err(e) => println!("  {:<24} failed: {e}", sc.name),
        }
    }
    println!(
        "mapper totals: {} searches, {} rounds, {} cached shapes",
        ev.sim.mapper.searches(),
        ev.sim.mapper.total_rounds(),
        ev.sim.mapper.cache_len()
    );
    Ok(())
}
