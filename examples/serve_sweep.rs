//! Serving under traffic: the cluster simulator and the SLO cost sweep.
//!
//! ```bash
//! cargo run --release --example serve_sweep
//! ```
//!
//! 1. Generates a Poisson trace of GPT-3-class requests and serves it on
//!    an 8×A100 node through the continuous-batching scheduler, reporting
//!    TTFT/TPOT tails and goodput under an interactive SLO.
//! 2. Replays the *same* traffic as a bursty process to show queueing
//!    sensitivity at identical mean rate.
//! 3. Runs the SLO-aware cost sweep across hardware presets and prints
//!    $/1M-output-tokens-at-SLO — the Table IV comparison, under load.

use llmcompass::graph::inference::Simulator;
use llmcompass::graph::ModelConfig;
use llmcompass::hardware::presets;
use llmcompass::serve::{
    self, sweep, Arrival, Policy, SchedulerConfig, Slo, WorkloadSpec,
};
use llmcompass::util::fmt_seconds;

fn main() {
    let sim = Simulator::pooled();
    let model = ModelConfig::gpt3_175b();
    let sys = presets::system("a100x8").expect("preset");
    let cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
    println!(
        "cluster: 8x {} | KV budget {} tokens | max batch {}",
        sys.device.name, cfg.kv_capacity_tokens, cfg.max_batch
    );

    // 1. Poisson traffic at 2 requests/s.
    let slo = Slo::interactive();
    let reqs = serve::workload::generate(&WorkloadSpec::poisson(2.0, 1000, 42));
    let t0 = std::time::Instant::now();
    let (report, _) = serve::serve_once(&sim, &sys, &model, &cfg, &reqs, &slo);
    let (summary, stats) = (report.summary, report.stats);
    println!("\n== 1,000 Poisson requests at 2.0 req/s ==");
    println!("{}", summary.render());
    println!(
        "prefill/decode iterations: {}/{} | peak KV {} tokens | simulated in {}",
        stats.prefill_iterations,
        stats.decode_iterations,
        stats.peak_kv_tokens,
        fmt_seconds(t0.elapsed().as_secs_f64())
    );

    // 2. Same mean rate, bursty arrivals.
    let bursty_spec = WorkloadSpec {
        arrival: Arrival::Bursty {
            rate_per_s: 2.0,
            burst_multiplier: 8.0,
            mean_phase_requests: 50.0,
        },
        ..WorkloadSpec::poisson(2.0, 1000, 42)
    };
    let bursty = serve::workload::generate(&bursty_spec);
    let (breport, _) = serve::serve_once(&sim, &sys, &model, &cfg, &bursty, &slo);
    let bsum = breport.summary;
    println!("\n== same rate, bursty (8x burst multiplier) ==");
    println!(
        "TTFT p99 {} (vs {} Poisson) | SLO attainment {:.1}% (vs {:.1}%)",
        fmt_seconds(bsum.ttft_p99_s),
        fmt_seconds(summary.ttft_p99_s),
        bsum.slo_attainment * 100.0,
        summary.slo_attainment * 100.0
    );

    // 3. The SLO-aware cost sweep across presets.
    println!("\n== $/1M output tokens at a relaxed SLO, across presets ==");
    let cfg = sweep::SweepConfig::paper_default(300, Slo::relaxed());
    let rows = sweep::run_sweep(&sim, &model, &cfg).expect("sweep");
    for best in sweep::best_per_system(&rows) {
        println!(
            "  {:<24} {:>10} at {:.1} req/s (cluster ${:.0})",
            best.system,
            if best.usd_per_mtok.is_finite() {
                format!("${:.3}", best.usd_per_mtok)
            } else {
                "unserved".to_string()
            },
            best.rate_per_s,
            best.cluster_cost_usd
        );
    }
    println!(
        "\n(the cost-effective Table IV designs should match or beat the GA100 \
         node here — the paper's Fig. 10-12 ordering, reproduced under traffic)"
    );

    // 4. Scheduler v2: monolithic vs chunked prefill vs disaggregated
    //    pools on the same node and traffic — the phase-splitting study.
    println!("\n== scheduler modes on a100x8, identical traffic ==");
    let cfg = sweep::SweepConfig::mode_comparison("a100x8", 300, Slo::relaxed());
    let rows = sweep::run_sweep(&sim, &model, &cfg).expect("mode sweep");
    for r in &rows {
        println!(
            "  {:<14} rate {:>4.1}/s  TTFT mean {}  preemptions {:>3}  ${}/1M tok",
            r.mode,
            r.rate_per_s,
            fmt_seconds(r.summary.ttft_mean_s),
            r.preemptions,
            if r.usd_per_mtok.is_finite() {
                format!("{:.3}", r.usd_per_mtok)
            } else {
                "inf".to_string()
            }
        );
    }
}
