//! Operator validation walkthrough: measure every AOT operator artifact on
//! the PJRT CPU backend, fit + tune the CPU device description, and print
//! the predicted-vs-measured table (the Fig. 5 pipeline as a script).
//!
//! ```bash
//! make artifacts && cargo run --release --example validate_operators
//! ```

use llmcompass::calibrate;
use llmcompass::graph::inference::Simulator;
use llmcompass::runtime::Runtime;
use llmcompass::util::stats;
use llmcompass::util::table::Table;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts found — run `make artifacts` first");
        std::process::exit(1);
    }
    let mut rt = Runtime::new(dir)?;
    println!("measuring {} operator artifacts on {}…", rt.manifest().artifacts.len(), rt.platform());
    let meas = calibrate::measure_operators(&mut rt, 3)?;

    let initial =
        calibrate::fit_cpu_device(&meas, llmcompass::util::pool::default_threads() as u64);
    println!(
        "initial fit: matrix peak {:.1} GFLOP/s, bw {:.2} GB/s — tuning…",
        initial.peak_matrix_flops() / 1e9,
        initial.memory.bandwidth_bytes_per_s / 1e9
    );
    let dev = calibrate::tune_cpu_device(initial, &meas);

    let sim = Simulator::new();
    let mut t = Table::new(&["artifact", "measured", "predicted", "ratio"])
        .with_title("predicted vs measured (tuned CPU device)");
    let mut ms = Vec::new();
    let mut ps = Vec::new();
    for m in &meas {
        let Some(pred) = calibrate::predict(&sim, &dev, &m.name) else { continue };
        ms.push(m.seconds);
        ps.push(pred);
        t.row(vec![
            m.name.clone(),
            llmcompass::util::fmt_seconds(m.seconds),
            llmcompass::util::fmt_seconds(pred),
            format!("{:.2}", pred / m.seconds),
        ]);
    }
    println!("{}", t.render());
    println!(
        "mean |error| {:.1}%, trend ρ = {:.2} across {} operators",
        stats::mean_rel_error(&ps, &ms) * 100.0,
        stats::spearman(&ms, &ps),
        ms.len()
    );
    Ok(())
}
