"""Layer-2 correctness: model shapes, KV-cache semantics, and the
prefill/decode consistency invariant (decoding token-by-token must produce
the same logits as prefilling the whole sequence)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model


CFG = model.Config(layers=2, d_model=64, heads=4, d_ff=128, vocab=256, max_seq=32)


@pytest.fixture(scope="module")
def flat():
    return model.init_flat(CFG, seed=0)


def toks(b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(b, s)), jnp.int32)


def test_param_layout_roundtrip(flat):
    p = model.unpack(CFG, flat)
    assert p["wte"].shape == (CFG.vocab, CFG.d_model)
    assert p["l0.wqkv"].shape == (CFG.d_model, 3 * CFG.d_model)
    total = sum(int(np.prod(v.shape)) for v in p.values())
    assert total == model.n_params(CFG) == flat.shape[0]


def test_init_deterministic():
    a = model.init_flat(CFG, seed=0)
    b = model.init_flat(CFG, seed=0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = model.init_flat(CFG, seed=1)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_prefill_shapes(flat):
    logits, kv_k, kv_v = model.prefill(CFG, flat, toks(2, 8))
    assert logits.shape == (2, CFG.vocab)
    assert kv_k.shape == (CFG.layers, 2, CFG.max_seq, CFG.d_model)
    # Positions beyond the prompt stay zero.
    assert float(jnp.abs(kv_k[:, :, 8:, :]).max()) == 0.0
    assert float(jnp.abs(kv_k[:, :, :8, :]).max()) > 0.0
    assert np.isfinite(np.asarray(logits)).all()


def test_decode_updates_one_position(flat):
    logits, kv_k, kv_v = model.prefill(CFG, flat, toks(2, 8))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, kv_k2, kv_v2 = model.decode(CFG, flat, tok, kv_k, kv_v, 8)
    assert logits2.shape == (2, CFG.vocab)
    # Position 8 newly filled; earlier positions unchanged.
    np.testing.assert_array_equal(np.asarray(kv_k2[:, :, :8]), np.asarray(kv_k[:, :, :8]))
    assert float(jnp.abs(kv_k2[:, :, 8]).max()) > 0.0
    assert float(jnp.abs(kv_k2[:, :, 9:]).max()) == 0.0


def test_prefill_decode_consistency(flat):
    """Prefilling s+1 tokens must equal prefilling s then decoding 1."""
    b, s = 2, 8
    prompt = toks(b, s + 1, seed=3)
    # Path A: prefill the full prompt.
    logits_full, _, _ = model.prefill(CFG, flat, prompt)
    # Path B: prefill the first s tokens, decode the (s+1)-th.
    _, kv_k, kv_v = model.prefill(CFG, flat, prompt[:, :s])
    logits_step, _, _ = model.decode(CFG, flat, prompt[:, s], kv_k, kv_v, s)
    np.testing.assert_allclose(
        np.asarray(logits_full), np.asarray(logits_step), rtol=2e-4, atol=2e-4
    )


def test_causality(flat):
    """Changing future tokens must not change the logits of the prefix's
    last position... i.e. prefill(prompt[:s]) is independent of what would
    come after, and position p output depends only on tokens ≤ p."""
    b, s = 1, 12
    p1 = toks(b, s, seed=4)
    p2 = jnp.concatenate([p1[:, : s - 1], (p1[:, -1:] + 1) % CFG.vocab], axis=1)
    # Same first s-1 tokens → identical KV prefix after prefilling s-1.
    _, kv1, _ = model.prefill(CFG, flat, p1[:, : s - 1])
    _, kv2, _ = model.prefill(CFG, flat, p2[:, : s - 1])
    np.testing.assert_allclose(np.asarray(kv1), np.asarray(kv2), rtol=1e-6, atol=1e-6)


def test_reference_generate_greedy(flat):
    out = model.reference_generate(CFG, flat, toks(2, 4, seed=5), 3)
    assert out.shape == (2, 3)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < CFG.vocab).all()
    # Deterministic.
    out2 = model.reference_generate(CFG, flat, toks(2, 4, seed=5), 3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_jit_wrappers(flat):
    pf = model.prefill_jit(CFG)
    logits, kv_k, kv_v = pf(flat, toks(2, 8))
    dc = model.decode_jit(CFG)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, _, _ = dc(flat, tok, kv_k, kv_v, 8)
    assert logits2.shape == (2, CFG.vocab)
