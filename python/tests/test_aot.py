"""AOT path: lowering produces loadable HLO text and a coherent manifest.

The heavier full-artifact build is exercised by `make artifacts`; here we
lower a handful of representative artifacts to a temp dir in quick mode and
validate structure (HLO text header, manifest arg metadata)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, kernels


def test_to_hlo_text_matmul():
    spec = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    lowered = jax.jit(lambda a, b: (kernels.matmul(a, b),)).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:60]
    assert "f32[16,16]" in text


def test_to_hlo_text_has_tuple_root():
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(lambda x: (x + 1.0,)).lower(spec)
    text = aot.to_hlo_text(lowered)
    # return_tuple=True wraps results in a 1-tuple.
    assert "(f32[4,4]" in text


def test_build_artifacts_quick(tmp_path):
    manifest = aot.build_artifacts(str(tmp_path), quick=True)
    files = set(os.listdir(tmp_path))
    assert "manifest.json" in files
    for art in manifest["artifacts"]:
        assert art["file"] in files, f"missing {art['file']}"
        head = open(tmp_path / art["file"]).read(64)
        assert head.startswith("HloModule"), art["name"]
        for a in art["args"]:
            assert "shape" in a and "dtype" in a
    names = {a["name"] for a in manifest["artifacts"]}
    assert "init" in names
    assert any(n.startswith("prefill_") for n in names)
    assert any(n.startswith("decode_") for n in names)
    assert any(n.startswith("matmul_") for n in names)
    # Manifest file round-trips as JSON.
    loaded = json.load(open(tmp_path / "manifest.json"))
    assert loaded["model"]["n_params"] == manifest["model"]["n_params"]
    assert loaded["model"]["n_params"] > 1_000_000
