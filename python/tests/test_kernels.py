"""Layer-1 correctness: every Pallas kernel vs the pure-jnp oracle,
swept over shapes, block sizes, and dtypes."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import kernels
from compile.kernels import ref

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - env without hypothesis
    HAVE_HYPOTHESIS = False


def rand(*shape, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


# --- matmul ---------------------------------------------------------------

@pytest.mark.parametrize(
    "m,k,n",
    [(8, 8, 8), (32, 64, 16), (64, 64, 64), (128, 256, 64), (100, 60, 28), (1, 384, 384)],
)
def test_matmul_matches_ref(m, k, n):
    a, b = rand(m, k, seed=1), rand(k, n, seed=2)
    got = kernels.matmul(a, b)
    want = ref.matmul(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bk,bn", [(8, 8, 8), (16, 64, 32), (128, 128, 128), (7, 13, 5)])
def test_matmul_block_size_invariance(bm, bk, bn):
    a, b = rand(64, 96, seed=3), rand(96, 48, seed=4)
    got = kernels.matmul(a, b, bm=bm, bk=bk, bn=bn)
    want = ref.matmul(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_bf16():
    a = rand(32, 64, seed=5).astype(jnp.bfloat16)
    b = rand(64, 32, seed=6).astype(jnp.bfloat16)
    got = kernels.matmul(a, b)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )


def test_matmul_vmem_estimate_positive():
    assert kernels.matmul_vmem_bytes(512, 512, 512) > 0
    # fp32 128³ blocking: (128·128)·2 inputs ·4B + 128² ·4B accumulator.
    assert kernels.matmul_vmem_bytes(128, 128, 128) == (128 * 128 * 2) * 4 + 128 * 128 * 4


def test_pick_block_divides():
    for extent in [1, 7, 64, 100, 384]:
        for pref in [1, 8, 128]:
            b = kernels.pick_block(extent, pref)
            assert extent % b == 0 and 1 <= b <= max(pref, 1)


# --- softmax ----------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(1, 8), (16, 128), (64, 1000), (128, 64), (333, 17)])
def test_softmax_matches_ref(m, n):
    x = rand(m, n, seed=7)
    got = kernels.softmax(x)
    want = ref.softmax(jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got).sum(-1), np.ones(m), rtol=1e-5)


def test_softmax_extreme_values_stable():
    x = np.array([[1e4, 1e4 - 1.0, -1e4], [0.0, 0.0, 0.0]], np.float32)
    got = np.asarray(kernels.softmax(x))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got[1], [1 / 3] * 3, rtol=1e-6)


# --- layernorm ---------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(4, 16), (64, 384), (256, 768), (100, 35)])
def test_layernorm_matches_ref(m, n):
    x = rand(m, n, seed=8)
    g = rand(n, seed=9) * 0.1 + 1.0
    b = rand(n, seed=10) * 0.1
    got = kernels.layernorm(x, g, b)
    want = ref.layernorm(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_layernorm_output_standardized():
    x = rand(32, 512, seed=11) * 5.0 + 3.0
    got = np.asarray(kernels.layernorm(x, np.ones(512, np.float32), np.zeros(512, np.float32)))
    np.testing.assert_allclose(got.mean(-1), np.zeros(32), atol=1e-4)
    np.testing.assert_allclose(got.std(-1), np.ones(32), atol=1e-3)


# --- gelu ---------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 1000, 16384])
def test_gelu_matches_ref(n):
    x = rand(n, seed=12) * 3.0
    got = kernels.gelu(x)
    want = ref.gelu(jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_gelu_known_values():
    x = np.array([0.0, 100.0, -100.0], np.float32)
    got = np.asarray(kernels.gelu(x))
    np.testing.assert_allclose(got, [0.0, 100.0, 0.0], atol=1e-4)


# --- attention -----------------------------------------------------------------

@pytest.mark.parametrize("m,n,d", [(8, 8, 16), (64, 64, 64), (32, 128, 64), (16, 64, 32)])
def test_attention_causal_matches_ref(m, n, d):
    q, k, v = rand(m, d, seed=13), rand(n, d, seed=14), rand(n, d, seed=15)
    got = kernels.attention(q, k, v, causal=True)
    want = ref.causal_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("bq,bkv", [(8, 8), (16, 64), (64, 16)])
def test_attention_block_size_invariance(bq, bkv):
    q, k, v = rand(64, 32, seed=16), rand(64, 32, seed=17), rand(64, 32, seed=18)
    got = kernels.attention(q, k, v, bq=bq, bkv=bkv, causal=True)
    want = ref.causal_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_noncausal_matches_ref():
    q, k, v = rand(32, 16, seed=19), rand(48, 16, seed=20), rand(48, 16, seed=21)
    got = kernels.attention(q, k, v, causal=False)
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_decode_shape():
    # m=1 decode against a long KV prefix.
    q, k, v = rand(1, 64, seed=22), rand(128, 64, seed=23), rand(128, 64, seed=24)
    got = kernels.attention(q, k, v, causal=True)
    want = ref.causal_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# --- hypothesis sweeps -----------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 96),
        k=st.integers(1, 96),
        n=st.integers(1, 96),
        bm=st.sampled_from([8, 16, 32, 128]),
    )
    def test_matmul_hypothesis(m, k, n, bm):
        a, b = rand(m, k, seed=m * 1000 + k), rand(k, n, seed=n)
        got = kernels.matmul(a, b, bm=bm)
        np.testing.assert_allclose(
            got, ref.matmul(jnp.asarray(a), jnp.asarray(b)), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=25, deadline=None)
    @given(m=st.integers(1, 200), n=st.integers(2, 300))
    def test_softmax_hypothesis(m, n):
        x = rand(m, n, seed=m * 301 + n)
        got = kernels.softmax(x)
        np.testing.assert_allclose(got, ref.softmax(jnp.asarray(x)), rtol=1e-4, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(1, 64), n=st.integers(2, 128))
    def test_layernorm_hypothesis(m, n):
        x = rand(m, n, seed=m * 77 + n)
        g = np.ones(n, np.float32)
        b = np.zeros(n, np.float32)
        got = kernels.layernorm(x, g, b)
        np.testing.assert_allclose(
            got, ref.layernorm(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b)),
            rtol=1e-3, atol=1e-4,
        )
