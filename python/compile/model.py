"""Layer-2: the JAX Transformer model (build-time only).

A GPT-style decoder-only model assembled from the Layer-1 Pallas kernels
(block-tiled matmul, layernorm, GELU, fused attention). Weights travel as a
single flat f32 vector so the AOT artifacts have a stable, simple ABI for
the Rust runtime: one `init` artifact materializes the vector, and the
`prefill` / `decode` artifacts take it as their first argument.

The KV cache is explicit state: `prefill` returns it, `decode` consumes and
returns it, with a static `max_seq` capacity and a `pos` scalar marking the
filled prefix — the Rust coordinator owns this state between calls, so
Python never runs at serving time.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import kernels


@dataclass(frozen=True)
class Config:
    """Model hyperparameters. The default is "gpt-mini" (~17M parameters in
    the layer stack): big enough to exercise every kernel, small enough
    that interpret-mode Pallas serves tokens in interactive time on CPU.
    The *simulated* model (GPT-3 175B) lives in the Rust layer; this is the
    model the end-to-end example actually executes."""

    layers: int = 6
    d_model: int = 384
    heads: int = 6
    d_ff: int = 1536
    vocab: int = 8192
    max_seq: int = 128

    @property
    def d_head(self):
        return self.d_model // self.heads


def param_spec(cfg: Config):
    """Ordered (name, shape) list defining the flat parameter layout."""
    spec = [
        ("wte", (cfg.vocab, cfg.d_model)),
        ("wpe", (cfg.max_seq, cfg.d_model)),
        ("ln_f_g", (cfg.d_model,)),
        ("ln_f_b", (cfg.d_model,)),
    ]
    for i in range(cfg.layers):
        spec += [
            (f"l{i}.ln1_g", (cfg.d_model,)),
            (f"l{i}.ln1_b", (cfg.d_model,)),
            (f"l{i}.wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln2_g", (cfg.d_model,)),
            (f"l{i}.ln2_b", (cfg.d_model,)),
            (f"l{i}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w2", (cfg.d_ff, cfg.d_model)),
        ]
    return spec


def n_params(cfg: Config):
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in param_spec(cfg))


def unpack(cfg: Config, flat):
    """Slice the flat vector into the named parameter dict (static)."""
    out = {}
    off = 0
    for name, shape in param_spec(cfg):
        size = 1
        for d in shape:
            size *= d
        out[name] = flat[off : off + size].reshape(shape)
        off += size
    return out


def init_flat(cfg: Config, seed: int = 0):
    """Materialize the flat parameter vector (scaled-normal init). Runs
    inside jit so the AOT `init` artifact carries no big constants."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        size = 1
        for d in shape:
            size *= d
        if name.endswith(("_g",)):
            chunks.append(jnp.ones((size,), jnp.float32))
        elif name.endswith(("_b",)):
            chunks.append(jnp.zeros((size,), jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.02 if name in ("wte", "wpe") else 1.0 / (fan_in ** 0.5)
            chunks.append(jax.random.normal(sub, (size,), jnp.float32) * std)
    return jnp.concatenate(chunks)


def _attention_block(cfg: Config, p, i, x, kv_k, kv_v, pos, q_len):
    """Shared attention block. x: (b, q_len, d). kv_k/kv_v: (layers, b,
    max_seq, d) with positions [0, pos) already filled; this call writes
    positions [pos, pos + q_len) and attends causally over [0, pos+q_len).
    Returns (attn_out, kv_k, kv_v)."""
    b = x.shape[0]
    d = cfg.d_model
    rows = b * q_len

    h = kernels.layernorm(x.reshape(rows, d), p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
    qkv = kernels.matmul(h, p[f"l{i}.wqkv"])  # (rows, 3d)
    q, k, v = jnp.split(qkv.reshape(b, q_len, 3 * d), 3, axis=-1)

    # Append K/V at positions [pos, pos + q_len) of layer i's cache.
    kv_k = kv_k.at[i].set(jax.lax.dynamic_update_slice_in_dim(kv_k[i], k, pos, axis=1))
    kv_v = kv_v.at[i].set(jax.lax.dynamic_update_slice_in_dim(kv_v[i], v, pos, axis=1))

    # Attend over the filled prefix [0, pos + q_len).
    dh = cfg.d_head
    n = cfg.max_seq
    q_h = q.reshape(b, q_len, cfg.heads, dh).transpose(0, 2, 1, 3)  # (b,h,q,dh)
    k_h = kv_k[i].reshape(b, n, cfg.heads, dh).transpose(0, 2, 1, 3)  # (b,h,n,dh)
    v_h = kv_v[i].reshape(b, n, cfg.heads, dh).transpose(0, 2, 1, 3)

    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("bhqd,bhnd->bhqn", q_h, k_h) * scale
    # Causal + validity mask: query at global position pos+qi sees keys ≤ it.
    qpos = pos + jnp.arange(q_len)[:, None]  # (q,1)
    kpos = jnp.arange(n)[None, :]  # (1,n)
    mask = kpos <= qpos  # (q, n)
    s = jnp.where(mask[None, None], s, -1e30)
    # Row-wise softmax through the Pallas kernel (rows = b·h·q).
    probs = kernels.softmax(s.reshape(b * cfg.heads * q_len, n)).reshape(s.shape)
    o = jnp.einsum("bhqn,bhnd->bhqd", probs, v_h)
    o = o.transpose(0, 2, 1, 3).reshape(rows, d)
    out = kernels.matmul(o, p[f"l{i}.wo"])
    return out.reshape(b, q_len, d), kv_k, kv_v


def _mlp_block(cfg: Config, p, i, x):
    b, q_len, d = x.shape
    rows = b * q_len
    h = kernels.layernorm(x.reshape(rows, d), p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
    h = kernels.matmul(h, p[f"l{i}.w1"])
    h = kernels.gelu(h.reshape(rows * cfg.d_ff)).reshape(rows, cfg.d_ff)
    h = kernels.matmul(h, p[f"l{i}.w2"])
    return h.reshape(b, q_len, d)


def _forward(cfg: Config, flat, tokens, kv_k, kv_v, pos, q_len):
    p = unpack(cfg, flat)
    b = tokens.shape[0]
    x = p["wte"][tokens]  # (b, q_len, d)
    positions = pos + jnp.arange(q_len)
    x = x + p["wpe"][positions][None]
    for i in range(cfg.layers):
        a, kv_k, kv_v = _attention_block(cfg, p, i, x, kv_k, kv_v, pos, q_len)
        x = x + a
        x = x + _mlp_block(cfg, p, i, x)
    h = kernels.layernorm(
        x.reshape(b * q_len, cfg.d_model), p["ln_f_g"], p["ln_f_b"]
    ).reshape(b, q_len, cfg.d_model)
    # Logits for the last position only (what generation needs).
    last = h[:, -1, :]  # (b, d)
    logits = kernels.matmul(last, p["wte"].T)  # (b, vocab)
    return logits, kv_k, kv_v


def empty_kv(cfg: Config, batch):
    shape = (cfg.layers, batch, cfg.max_seq, cfg.d_model)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def prefill(cfg: Config, flat, tokens):
    """Process a (b, s) prompt: returns (last-token logits (b, vocab),
    kv_k, kv_v) with positions [0, s) of the KV cache filled."""
    b, s = tokens.shape
    kv_k, kv_v = empty_kv(cfg, b)
    return _forward(cfg, flat, tokens, kv_k, kv_v, 0, s)


def decode(cfg: Config, flat, token, kv_k, kv_v, pos):
    """Generate one step: token (b,) int32, pos = number of cached
    positions. Returns (logits (b, vocab), kv_k, kv_v)."""
    return _forward(cfg, flat, token[:, None], kv_k, kv_v, pos, 1)


def reference_generate(cfg: Config, flat, prompt, n_tokens):
    """Greedy generation loop in Python — the oracle the Rust coordinator's
    token stream is checked against in integration tests."""
    logits, kv_k, kv_v = prefill(cfg, flat, prompt)
    out = []
    pos = prompt.shape[1]
    for _ in range(n_tokens):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
        logits, kv_k, kv_v = decode(cfg, flat, tok, kv_k, kv_v, pos)
        pos += 1
    return jnp.stack(out, axis=1)  # (b, n_tokens)


def prefill_jit(cfg: Config):
    return jax.jit(functools.partial(prefill, cfg))


def decode_jit(cfg: Config):
    return jax.jit(functools.partial(decode, cfg))
