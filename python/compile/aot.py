"""AOT lowering: JAX/Pallas → HLO **text** artifacts + manifest.

Python runs once, here; the Rust runtime loads the text artifacts via
`HloModuleProto::from_text_file` and executes them through PJRT. HLO text
(not `.serialize()`) is the interchange format: jax ≥ 0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts emitted (see the manifest for the authoritative list):
  * `init`            — () → flat f32 parameter vector of gpt-mini
  * `prefill_b{B}_s{S}` — (flat, tokens i32[B,S]) → (logits, kv_k, kv_v)
  * `decode_b{B}`     — (flat, token i32[B], kv_k, kv_v, pos i32) → (…)
  * operator kernels for the Fig.-5-style calibration sweep:
    `matmul_{M}x{K}x{N}`, `softmax_{M}x{N}`, `layernorm_{M}x{N}`,
    `gelu_{N}`, `attention_{M}x{N}x{D}`
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import kernels, model

# Calibration sweep sizes — small enough for interpret-mode CPU execution,
# wide enough to expose the latency trends of paper Fig. 5.
MATMUL_SIZES = [
    (16, 768, 768),
    (64, 768, 768),
    (256, 768, 768),
    (1024, 768, 768),
    (256, 256, 256),
    (512, 512, 512),
    (1024, 1024, 1024),
]
SOFTMAX_SIZES = [(64, 512), (256, 1024), (1024, 1024), (4096, 256)]
LAYERNORM_SIZES = [(64, 768), (256, 768), (1024, 768), (4096, 768)]
GELU_SIZES = [1 << 14, 1 << 17, 1 << 20]
ATTENTION_SIZES = [(64, 64, 64), (128, 128, 64), (256, 256, 64)]

# Serving model shapes.
PREFILL_BATCHES = [(4, 64)]
DECODE_BATCHES = [4]


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text with a tuple root."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _arg_meta(args):
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in args]


def build_artifacts(out_dir: str, quick: bool = False) -> dict:
    """Lower every artifact into `out_dir`; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    cfg = model.Config()
    manifest = {
        "model": {
            "layers": cfg.layers,
            "d_model": cfg.d_model,
            "heads": cfg.heads,
            "d_ff": cfg.d_ff,
            "vocab": cfg.vocab,
            "max_seq": cfg.max_seq,
            "n_params": int(model.n_params(cfg)),
        },
        "artifacts": [],
    }

    def emit(name, fn, *arg_specs):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {"name": name, "file": fname, "args": _arg_meta(arg_specs)}
        )
        print(f"  {name}: {len(text) / 1024:.0f} KiB")

    f32 = jnp.float32
    i32 = jnp.int32

    # --- serving model ------------------------------------------------------
    nparams = model.n_params(cfg)
    emit("init", lambda: (model.init_flat(cfg),))
    kv_shape = (cfg.layers, DECODE_BATCHES[0], cfg.max_seq, cfg.d_model)
    for b, s in PREFILL_BATCHES:
        emit(
            f"prefill_b{b}_s{s}",
            functools.partial(model.prefill, cfg),
            _spec((nparams,), f32),
            _spec((b, s), i32),
        )
    for b in DECODE_BATCHES:
        emit(
            f"decode_b{b}",
            functools.partial(model.decode, cfg),
            _spec((nparams,), f32),
            _spec((b,), i32),
            _spec(kv_shape, f32),
            _spec(kv_shape, f32),
            _spec((), i32),
        )

    # --- calibration operators ---------------------------------------------
    matmuls = MATMUL_SIZES[:3] if quick else MATMUL_SIZES
    for m, k, n in matmuls:
        emit(
            f"matmul_{m}x{k}x{n}",
            lambda a, b: (kernels.matmul(a, b),),
            _spec((m, k), f32),
            _spec((k, n), f32),
        )
    for m, n in SOFTMAX_SIZES if not quick else SOFTMAX_SIZES[:2]:
        emit(
            f"softmax_{m}x{n}",
            lambda x: (kernels.softmax(x),),
            _spec((m, n), f32),
        )
    for m, n in LAYERNORM_SIZES if not quick else LAYERNORM_SIZES[:2]:
        emit(
            f"layernorm_{m}x{n}",
            lambda x, g, b: (kernels.layernorm(x, g, b),),
            _spec((m, n), f32),
            _spec((n,), f32),
            _spec((n,), f32),
        )
    for n in GELU_SIZES if not quick else GELU_SIZES[:1]:
        emit(f"gelu_{n}", lambda x: (kernels.gelu(x),), _spec((n,), f32))
    for m, n, d in ATTENTION_SIZES if not quick else ATTENTION_SIZES[:1]:
        emit(
            f"attention_{m}x{n}x{d}",
            lambda q, k, v: (kernels.attention(q, k, v),),
            _spec((m, d), f32),
            _spec((n, d), f32),
            _spec((n, d), f32),
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true", help="skip the larger sweep sizes")
    args = ap.parse_args()
    manifest = build_artifacts(args.out, quick=args.quick)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
