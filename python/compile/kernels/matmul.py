"""Layer-1 Pallas kernel: block-tiled matmul.

TPU-thinking version of the operator LLMCompass models in §III-B1: the
(m, n, k) grid expresses the HBM↔VMEM schedule via BlockSpecs — each grid
step holds one (bm × bk) A block and one (bk × bn) B block in VMEM-class
scratch and accumulates a (bm × bn) C block in float32, exactly the
local-buffer-resident-accumulator schedule the Rust simulator's "scheme 1"
models. `interpret=True` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime can run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps):
    """One (mi, ni, ki) grid step: acc += A_block @ B_block."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(ki == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def pick_block(extent, preferred):
    """Largest divisor of `extent` that is ≤ `preferred` — Pallas blocks
    must tile the problem exactly."""
    b = max(1, min(extent, preferred))
    while extent % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(a, b, bm=256, bk=256, bn=256):
    """C = A @ B via the Pallas block-tiled kernel.

    a: (m, k), b: (k, n). Requested block sizes are clamped to divisors of
    the problem extents.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm = pick_block(m, bm)
    bk = pick_block(k, bk)
    bn = pick_block(n, bn)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(a, b)


def matmul_vmem_bytes(m, k, n, bm=256, bk=256, bn=256, elem_bytes=4):
    """Estimated VMEM footprint of one grid step (for the §Perf roofline
    discussion in DESIGN.md): A block + B block + fp32 accumulator."""
    bm = pick_block(m, bm)
    bk = pick_block(k, bk)
    bn = pick_block(n, bn)
    return (bm * bk + bk * bn) * elem_bytes + bm * bn * 4
