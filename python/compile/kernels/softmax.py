"""Layer-1 Pallas kernel: row-blocked online softmax.

Implements the online normalizer algorithm [Milakov & Gimelshein 2018] the
paper cites for its Softmax model: a single streaming pass over the row
maintains the running max `m` and running sum `l`, then the row is
normalized. Rows are processed in (block_rows × n) VMEM blocks — the
column (reduction) axis stays whole per block, matching how the Rust
simulator's vecop model assigns one row per lane with a log-tree reduce.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    l = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (e / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def softmax(x, block_rows=512):
    """Row-wise softmax over the last axis of a 2-D array."""
    m, n = x.shape
    br = pick_block(m, block_rows)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x)
