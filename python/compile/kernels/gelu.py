"""Layer-1 Pallas kernel: elementwise GELU (tanh approximation [26]),
processed in 1-D VMEM blocks."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    c = jnp.sqrt(2.0 / jnp.pi).astype(jnp.float32)
    y = 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def gelu(x, block=131072):
    """Elementwise GELU over a 1-D array."""
    (n,) = x.shape
    b = pick_block(n, block)
    return pl.pallas_call(
        _gelu_kernel,
        grid=(n // b,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)
