"""Layer-1 Pallas kernels (build-time only; lowered into the AOT HLO).

All kernels run with `interpret=True` — the CPU PJRT backend cannot execute
Mosaic (real-TPU) custom-calls. Correctness is pinned to `ref.py` by the
pytest suite in `python/tests/`.
"""

from .matmul import matmul, matmul_vmem_bytes, pick_block
from .softmax import softmax
from .layernorm import layernorm
from .gelu import gelu
from .attention import attention

__all__ = [
    "matmul",
    "matmul_vmem_bytes",
    "pick_block",
    "softmax",
    "layernorm",
    "gelu",
    "attention",
]
