"""Pure-jnp reference oracle for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
checks `assert_allclose(kernel(...), ref(...))` across shape/dtype sweeps.
This is the *core correctness signal* for Layer 1: the AOT path lowers the
kernels into the same HLO the Rust runtime executes, so kernel == ref means
the artifacts compute the right numbers.
"""

import jax.numpy as jnp


def matmul(a, b):
    """C = A @ B with float32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def softmax(x):
    """Row-wise softmax over the last axis (numerically stable)."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def gelu(x):
    """GELU with the tanh approximation [Hendrycks & Gimpel 2016]."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def attention(q, k, v, scale=None):
    """Scaled dot-product attention for one head.

    q: (m, d), k: (n, d), v: (n, d) -> (m, d).
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    s = jnp.matmul(q, k.T, preferred_element_type=jnp.float32) * scale
    p = softmax(s)
    return jnp.matmul(p.astype(q.dtype), v, preferred_element_type=jnp.float32).astype(q.dtype)


def causal_attention(q, k, v, scale=None):
    """Causal attention: query i attends to keys ≤ i (queries right-aligned
    against the keys, so the last query sees every key)."""
    m, d = q.shape[-2], q.shape[-1]
    n = k.shape[-2]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    s = jnp.matmul(q, k.T, preferred_element_type=jnp.float32) * scale
    offs = n - m
    mask = jnp.arange(n)[None, :] <= (jnp.arange(m)[:, None] + offs)
    s = jnp.where(mask, s, jnp.finfo(jnp.float32).min)
    p = softmax(s)
    return jnp.matmul(p.astype(q.dtype), v, preferred_element_type=jnp.float32).astype(q.dtype)
