"""Layer-1 Pallas kernel: fused causal attention (FlashAttention-style
online-softmax blocking, re-thought for a TPU VMEM schedule).

The paper's GPU comparators implement attention with threadblock tiling of
Q against K/V in shared memory; here the same insight — never materialize
the (m × n) score matrix in HBM — is expressed with a (q-block, kv-block)
Pallas grid: each step holds one Q block and one K/V block in VMEM and
maintains the online-softmax running max/denominator and the output
accumulator in scratch. Causality is enforced with a right-aligned mask so
the kernel serves both prefill (m == n) and decode (m == 1, n == kv_len).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .matmul import pick_block

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, kv_steps, bq, bkv, n, m,
                 scale, causal):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        # Right-aligned causal mask: query row (global) r sees key col c
        # iff c <= r + (n - m).
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        cols = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(cols <= rows + (n - m), s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    correction = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * correction[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "causal"))
def attention(q, k, v, bq=128, bkv=128, causal=True):
    """Fused attention for one head: q (m, d), k/v (n, d) → (m, d)."""
    m, d = q.shape
    n, d2 = k.shape
    assert d == d2 and v.shape == (n, d)
    bq = pick_block(m, bq)
    bkv = pick_block(n, bkv)
    kv_steps = n // bkv
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(
            _attn_kernel,
            kv_steps=kv_steps,
            bq=bq,
            bkv=bkv,
            n=n,
            m=m,
            scale=scale,
            causal=causal,
        ),
        grid=(m // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((bq, d), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((bkv, d), lambda qi, ki: (ki, 0)),
            pl.BlockSpec((bkv, d), lambda qi, ki: (ki, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda qi, ki: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
