"""Layer-1 Pallas kernel: row-blocked LayerNorm.

Two-pass-in-registers structure over a (block_rows × n) VMEM block: mean
and variance in float32, then normalize + scale/shift — the same
reduction-then-normalize schedule the Rust vecop model costs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) / jnp.sqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def layernorm(x, gamma, beta, block_rows=512, eps=1e-5):
    """LayerNorm over the last axis of a 2-D array; gamma/beta: (n,)."""
    m, n = x.shape
    br = pick_block(m, block_rows)
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(m // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, gamma, beta)
